"""Rung 2: shape-family plan reuse with cost-model certification.

A cached neighbor — same kernel template, same hardware, different shape
— usually encodes the right *mapping decisions* (which mesh dims bind
which loop dims, where loads hoist, what broadcasts along which axis)
even when its extents differ.  :func:`retarget_plan` transplants those
decisions onto the requested shape: keep the neighbor's spatial binds,
recompute the residual temporal loops from the new extents, and re-pick
the memory-op combo closest to the neighbor's among the feasible ones.

The transplant is only *served* if it certifies: the wave-class
simulator re-costs it on the requested shape and the result must fall
within ``regret x`` an admissible per-program floor (peak-compute time
vs. aggregate-DRAM time, plus the launch overhead every plan pays).
Any plan's simulated time is at least the floor, so certification
``sim <= regret * floor`` implies ``sim <= regret * exact`` — the
family answer is provably within the regret bound of the plan a full
search would have found, without running that search.
"""
from __future__ import annotations

import math
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.hw import HardwareModel
from repro.core.mapping import Mapping, TemporalLoop
from repro.core.perfmodel import estimate
from repro.core.plan import DataflowPlan
from repro.core.planner import Candidate, PlanResult
from repro.core.program import TileProgram
from repro.core.reuse import memop_choices_with_stores
from repro.core.simulator import SimResult, simulate
from repro.plancache import serialize, warmstart
from repro.plancache.validate import validate_plan

#: Launch overhead every simulated plan pays (simulator.simulate default);
#: folded into the floor so tiny kernels don't fail certification on a
#: constant no plan can avoid.
LAUNCH_OVERHEAD_S = 20e-6


def program_floor(program: TileProgram, hw: HardwareModel) -> float:
    """Admissible lower bound on any plan's simulated time for
    ``program`` on ``hw``: the slower of peak-compute time and the time
    to move each unique tensor through aggregate DRAM bandwidth once,
    plus the launch overhead."""
    compute_s = program.total_flops() / hw.peak_flops()
    unique: Dict[str, int] = {}
    for a in program.loads + program.stores:
        t = a.tensor
        unique[t.name] = math.prod(t.shape) * t.dtype_bytes
    bw = hw.global_mem.bandwidth_gbps * 1e9 * hw.global_channels()
    dram_s = sum(unique.values()) / bw
    return LAUNCH_OVERHEAD_S + max(compute_s, dram_s)


def retarget_plan(entry: Dict[str, Any], programs: Sequence[TileProgram],
                  hw: HardwareModel) -> Optional[DataflowPlan]:
    """Transplant a cached neighbor's plan onto the requested programs.
    Returns None whenever anything about the neighbor doesn't transfer —
    the family rung simply moves to the next neighbor."""
    try:
        nbr = serialize.result_from_dict(entry["payload"]["result"])
    except (KeyError, TypeError, ValueError):
        return None
    meta = entry.get("meta")
    tiles = meta.get("tiles") if isinstance(meta, dict) else None
    ordered = warmstart.order_programs(list(programs), tiles)
    if not ordered:
        return None
    prog = ordered[0]
    nmap = nbr.best.plan.mapping

    mesh = dict(hw.mesh_dims)
    grid = {d.name: d.extent for d in prog.grid_dims}
    seq = {d.name: d.extent for d in prog.seq_dims}
    binds = []
    for b in nmap.spatial:
        if b.hw_dim not in mesh or not 1 <= b.hw_size <= mesh[b.hw_dim]:
            return None
        if b.reduce:
            # a split reduction only pays when the requested reduction is
            # at least as deep as the split; otherwise drop the bind
            if seq.get(b.grid_dim, 0) >= b.hw_size:
                binds.append(b)
        else:
            if b.grid_dim not in grid:
                return None
            binds.append(b)
    if not any(not b.reduce for b in binds):
        return None
    reduce_style = nmap.reduce_style if any(b.reduce for b in binds) else ""

    factor: Dict[str, int] = {}
    for b in binds:
        if not b.reduce:
            factor[b.grid_dim] = factor.get(b.grid_dim, 1) * b.hw_size
    temporal = []
    for d in prog.grid_dims:
        ext = -(-d.extent // factor.get(d.name, 1))
        if ext > 1:
            temporal.append(TemporalLoop(f"t_{d.name}", d.name, ext))
    mapping = Mapping(prog, hw.name, hw.mesh_dims, tuple(binds),
                      tuple(temporal), reduce_style)
    if mapping.conflicts_with_faults(hw):
        return None
    try:
        combos, stores = memop_choices_with_stores(mapping, hw,
                                                   max_per_load=8)
    except (RuntimeError, ValueError):
        return None
    if not combos:
        return None

    # re-pick the combo closest to the neighbor's realized choices
    want = {c.access.tensor.name: (tuple(c.bcast_axes), c.hoist.level)
            for c in nbr.best.plan.loads}

    def match(combo) -> int:
        s = 0
        for c in combo:
            w = want.get(c.access.tensor.name)
            if w is None:
                continue
            if tuple(c.bcast_axes) == w[0]:
                s += 2
            if c.hoist.level == w[1]:
                s += 1
        return s

    best = max(combos, key=match)      # ties: first in stream order
    plan = DataflowPlan(mapping, best, stores)
    if validate_plan(plan, hw):
        return None
    return plan


def certify_plan(plan: DataflowPlan, hw: HardwareModel,
                 regret: float) -> Tuple[bool, SimResult, float]:
    """Simulate the transplanted plan on the requested shape and accept
    it only within ``regret x`` the admissible floor."""
    sim = simulate(plan, hw)
    floor = program_floor(plan.program, hw)
    return sim.total_s <= regret * max(floor, 1e-12), sim, floor


def certified_result(entry: Dict[str, Any],
                     programs: Sequence[TileProgram],
                     hw: HardwareModel, *,
                     regret: float) -> Optional[PlanResult]:
    """retarget + validate + certify, packaged as a PlanResult the
    service can return (or None when the neighbor doesn't transfer)."""
    t0 = time.perf_counter()
    plan = retarget_plan(entry, programs, hw)
    if plan is None:
        return None
    ok, sim, floor = certify_plan(plan, hw, regret)
    if not ok:
        return None
    cost = estimate(plan, hw)
    cand = Candidate(plan=plan, cost=cost, sim=sim, index=(0, 0, 0))
    log: List[str] = [
        f"family: certified sim {sim.total_s * 1e6:.1f}us <= "
        f"{regret:g}x floor {floor * 1e6:.1f}us "
        f"(neighbor {entry.get('key', '?')[:12]})"]
    return PlanResult(kernel=plan.program.name, hw_name=hw.name, best=cand,
                      topk=[cand], n_candidates=1, n_mappings=1,
                      plan_seconds=time.perf_counter() - t0, log=log)
