"""The deadline-bounded plan service (DESIGN_PLANSERVICE.md).

``PlanService.resolve`` walks a four-rung ladder under a
``time.monotonic`` deadline and **always returns a PlanResponse, never
raises**:

1. ``cache``    — exact plancache hit (integrity-checked + sanitized);
2. ``family``   — a cached shape-neighbor's plan transplanted onto the
   requested shape and certified against a regret bound (family.py);
3. ``search``   — a bounded incremental search, budget trimmed to the
   remaining deadline (``core.planner.budget_for_deadline``); degraded
   fabrics route into the PR 7 ladder (``runtime.replan.plan_degraded``)
   instead of a cold search;
4. ``fallback`` — the guaranteed generic plan (fallback.py).

Robustness machinery: concurrent identical requests coalesce onto one
in-flight resolution; a semaphore admission gate bounds concurrent cold
searches (overload sheds to the fallback rung); a per-(template, hw)
circuit breaker skips the search rung after repeated deadline misses and
half-opens on a cooldown timer; and when the deadline forces a fallback
or family answer, the full search continues on a background thread and
publishes to the plancache so the next identical request is a rung-1
hit.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, List, Optional, Sequence, Set,
                    Tuple)

from repro.core.hw import HardwareModel
from repro.core.planner import (PlanResult, SearchBudget, budget_for_deadline,
                                effective_budget, plan_kernel_multi)
from repro.core.program import TileProgram
from repro.obs import context, flightrec, metrics, slo, trace
from repro.plancache import PlanCache, keying

RUNGS = ("cache", "family", "search", "fallback")

ENV_DEADLINE = "REPRO_PLAN_DEADLINE_MS"
ENV_REGRET = "REPRO_PLAN_REGRET"
ENV_BG = "REPRO_PLAN_BG"


def default_deadline_ms() -> float:
    try:
        return float(os.environ.get(ENV_DEADLINE, "") or 10.0)
    except ValueError:
        return 10.0


def default_regret() -> float:
    try:
        return float(os.environ.get(ENV_REGRET, "") or 3.0)
    except ValueError:
        return 3.0


def background_enabled() -> bool:
    return os.environ.get(ENV_BG, "").lower() not in (
        "0", "off", "false", "no")


@dataclass
class PlanRequest:
    """One plan resolution request.  ``budget_ms=None`` means the env
    default (:data:`ENV_DEADLINE`, ~10ms); ``float("inf")`` disables the
    deadline entirely — full-budget resolution through the service is
    then bit-identical to calling ``plan_kernel_multi`` directly."""
    programs: Sequence[TileProgram]
    hw: HardwareModel
    budget: Optional[SearchBudget] = None
    budget_ms: Optional[float] = None
    profile: bool = True
    spatial_reuse: bool = True
    temporal_reuse: bool = True
    regret_bound: Optional[float] = None   # None -> env default (~3x)
    background: Optional[bool] = None      # None -> env default (on)


@dataclass
class PlanResponse:
    """What resolve() always returns.  ``result`` is None only for
    ``outcome="infeasible"`` (no candidate program fits the hardware at
    all — the one case where "always return a runnable plan" has no
    plan to return, reported instead of invented)."""
    result: Optional[PlanResult]
    rung: str                   # member of RUNGS
    outcome: str                # ok|coalesced|deadline|shed|breaker_open|
    #                             infeasible|error
    hw: HardwareModel           # the model the plan targets (may be a
    #                             submesh of the requested fabric)
    seconds: float
    deadline_ms: float
    key: str
    log: List[str] = field(default_factory=list)
    background: bool = False    # a background completion was scheduled

    @property
    def plan(self):
        return self.result.best.plan if self.result is not None else None

    @property
    def ok(self) -> bool:
        return self.result is not None


@dataclass
class MeshPlanResponse:
    """resolve_mesh()'s answer: the mesh-parallel ranking plus the same
    rung/latency accounting single-kernel responses carry."""
    ranking: Any
    rung: str
    outcome: str
    seconds: float


class _Flight:
    """One in-flight resolution identical requests coalesce onto."""
    __slots__ = ("event", "response")

    def __init__(self) -> None:
        self.event = threading.Event()
        self.response: Optional[PlanResponse] = None


class _Breaker:
    """Per-(template, hw) circuit breaker over rung-3 deadline misses.

    closed -> (threshold misses) -> open -> (cooldown) -> half_open
    -> one trial -> closed on success / open on another miss.

    Every state transition lands in the flight recorder (kind
    ``breaker``) and in ``planservice_breaker_transitions_total`` — a
    breaker flapping open is the single most explanatory event in a
    deadline-miss incident.
    """

    def __init__(self, threshold: int, cooldown_s: float,
                 clock: Callable[[], float], key: str = "") -> None:
        self.threshold = threshold
        self.cooldown_s = cooldown_s
        self.clock = clock
        self.key = key
        self.state = "closed"
        self.misses = 0
        self.opened_at = 0.0

    def _set_state(self, state: str) -> None:
        if state == self.state:
            return
        prev, self.state = self.state, state
        flightrec.record("breaker", key=self.key,
                         **{"from": prev, "to": state})
        metrics.inc("planservice_breaker_transitions_total", to=state)

    def force_open(self) -> None:
        """Re-open without counting a miss (half-open trial gave its
        slot back)."""
        self._set_state("open")
        self.opened_at = self.clock()

    def allow(self) -> bool:
        if self.state == "closed":
            return True
        if self.state == "open":
            if self.clock() - self.opened_at >= self.cooldown_s:
                self._set_state("half_open")  # admit exactly one trial
                return True
            return False
        return False                         # half_open trial in flight

    def record_ok(self) -> None:
        self._set_state("closed")
        self.misses = 0

    def record_miss(self) -> None:
        self.misses += 1
        if self.state == "half_open" or self.misses >= self.threshold:
            self._set_state("open")
            self.opened_at = self.clock()
            self.misses = 0


class PlanService:
    """In-process plan server; thread-safe; one instance per process is
    the intended deployment (``launch/serve.py`` owns one)."""

    def __init__(self, cache: Optional[PlanCache] = None, *,
                 max_concurrent_searches: int = 2,
                 breaker_threshold: int = 3,
                 breaker_cooldown_s: float = 30.0,
                 clock: Callable[[], float] = time.monotonic) -> None:
        self.cache = cache if cache is not None else PlanCache()
        self.clock = clock
        self.breaker_threshold = breaker_threshold
        self.breaker_cooldown_s = breaker_cooldown_s
        # max_concurrent_searches=0 is a legal test/overload configuration
        # (shed every search); BoundedSemaphore(0) is not constructible
        self._no_search = max_concurrent_searches <= 0
        self._gate = threading.BoundedSemaphore(
            max(1, max_concurrent_searches))
        self._lock = threading.Lock()
        self._flights: Dict[str, _Flight] = {}
        self._fallbacks: Dict[str, Tuple[Optional[PlanResult],
                                         HardwareModel]] = {}
        self._breakers: Dict[str, _Breaker] = {}
        self._ewma: Dict[str, float] = {}    # predicted search seconds
        self._bg_keys: Set[str] = set()
        self._bg_threads: List[threading.Thread] = []

    # ------------------------------------------------------------- public
    def resolve(self, request: PlanRequest) -> PlanResponse:
        """Walk the ladder.  Never raises; always within ~one rung-check
        of the deadline (each rung re-checks remaining time before it
        starts, so only the granularity of a single check can overrun).

        Runs inside a correlation scope: a fresh ``plan-*`` request ID
        unless the caller already holds one (a resolve nested inside a
        tenancy/replan incident inherits the incident ID)."""
        with context.correlate("plan"):
            return self._resolve(request)

    def _resolve(self, request: PlanRequest) -> PlanResponse:
        t0 = self.clock()
        deadline_ms = (request.budget_ms if request.budget_ms is not None
                       else default_deadline_ms())
        budget = effective_budget(request.budget)
        try:
            key = keying.kernel_key(
                list(request.programs), request.hw, budget,
                profile=request.profile,
                spatial_reuse=request.spatial_reuse,
                temporal_reuse=request.temporal_reuse)
        except Exception as e:  # noqa: BLE001 — resolve must not raise
            resp = self._fallback_response(
                request, "", t0, deadline_ms, budget,
                log=[f"keying error: {e!r}"], outcome="error")
            self._note(resp)
            return resp

        # ---- in-flight coalescing ---------------------------------------
        with self._lock:
            flight = self._flights.get(key)
            leader = flight is None
            if leader:
                flight = self._flights[key] = _Flight()
        if not leader:
            timeout = (None if deadline_ms == float("inf")
                       else max(0.0, deadline_ms / 1e3
                                - (self.clock() - t0)))
            if flight.event.wait(timeout) and flight.response is not None:
                resp = dataclasses.replace(
                    flight.response, outcome="coalesced",
                    seconds=self.clock() - t0, deadline_ms=deadline_ms)
            else:
                resp = self._fallback_response(
                    request, key, t0, deadline_ms, budget,
                    log=["coalesced wait expired before leader finished"],
                    outcome="deadline")
            self._note(resp)
            return resp

        resp: Optional[PlanResponse] = None
        try:
            resp = self._ladder(request, key, t0, deadline_ms, budget)
        except Exception as e:  # noqa: BLE001 — the contract: never raise
            resp = self._fallback_response(
                request, key, t0, deadline_ms, budget,
                log=[f"ladder error: {e!r}"], outcome="error")
        finally:
            flight.response = resp
            with self._lock:
                self._flights.pop(key, None)
            flight.event.set()
        self._note(resp)
        return resp

    def resolve_mesh(self, api, shape, tcfg, *, multi_pod: bool = False,
                     top_k: int = 3,
                     budget_ms: Optional[float] = None) -> MeshPlanResponse:
        """Mesh-parallel requests (``parallel.planner_bridge.plan_mesh``)
        through the service's accounting: rung from the plancache probe,
        latency against the deadline, same metric families.  Never
        raises."""
        from repro.plancache import lookup_source
        with context.correlate("plan"):
            t0 = self.clock()
            deadline_ms = (budget_ms if budget_ms is not None
                           else default_deadline_ms())
            ranking, rung, outcome = None, "fallback", "error"
            try:
                from repro.parallel.planner_bridge import plan_mesh
                with lookup_source(self.cache.store) as probe:
                    ranking = plan_mesh(api, shape, tcfg,
                                        multi_pod=multi_pod, top_k=top_k)
                rung = "cache" if probe["source"] == "cache" else "search"
                outcome = "ok"
            except Exception:  # noqa: BLE001
                pass
            resp = MeshPlanResponse(ranking=ranking, rung=rung,
                                    outcome=outcome,
                                    seconds=self.clock() - t0)
            metrics.inc("planservice_requests_total", rung=rung,
                        outcome=outcome)
            metrics.observe("planservice_resolve_seconds", resp.seconds,
                            rung=rung)
            missed = (deadline_ms != float("inf")
                      and resp.seconds * 1e3 > deadline_ms)
            if missed:
                metrics.inc("planservice_deadline_miss_total", rung=rung)
            flightrec.record("plan_request", mode="mesh", rung=rung,
                             outcome=outcome, seconds=resp.seconds,
                             deadline_ms=deadline_ms)
            slo.note_request(ok=(outcome == "ok" and not missed),
                             rung=rung, seconds=resp.seconds)
            return resp

    def note_fault(self, outcome: Any) -> None:
        """Fault-event subscription (``runtime.replan`` orchestration):
        the fabric changed, so per-(template, hw) breaker states and
        search-time estimates keyed to the old digest are stale — reset
        them and count the event.  Subsequent degraded-key requests hit
        rung 3's ``plan_degraded`` routing (and rung 1 once the ladder's
        published pool lands)."""
        metrics.inc("planservice_fault_events_total",
                    cause=getattr(outcome, "cause", "unknown"))
        with self._lock:
            self._breakers.clear()
            self._ewma.clear()

    def drain(self, timeout_s: float = 60.0) -> bool:
        """Join outstanding background completions (tests/benchmarks).
        Real wall-clock, regardless of any injected ``clock``."""
        end = time.monotonic() + timeout_s
        with self._lock:
            threads = list(self._bg_threads)
        for th in threads:
            th.join(max(0.0, end - time.monotonic()))
        with self._lock:
            self._bg_threads = [t for t in self._bg_threads if t.is_alive()]
            return not self._bg_threads

    # ------------------------------------------------------------- ladder
    def _ladder(self, request: PlanRequest, key: str, t0: float,
                deadline_ms: float, budget: SearchBudget) -> PlanResponse:
        programs = list(request.programs)
        hw = request.hw
        log: List[str] = []

        def left() -> float:
            if deadline_ms == float("inf"):
                return float("inf")
            return deadline_ms / 1e3 - (self.clock() - t0)

        def respond(result: PlanResult, rung: str, outcome: str = "ok",
                    background: bool = False,
                    target: Optional[HardwareModel] = None) -> PlanResponse:
            return PlanResponse(
                result=result, rung=rung, outcome=outcome,
                hw=target if target is not None else hw,
                seconds=self.clock() - t0, deadline_ms=deadline_ms, key=key,
                log=list(log), background=background)

        with trace.span("planservice.resolve", cat="planservice",
                        deadline_ms=deadline_ms):
            if not programs:
                return self._fallback_response(
                    request, key, t0, deadline_ms, budget,
                    log=["empty program list"], outcome="infeasible")

            # ---- rung 1: exact plancache hit ----------------------------
            if left() > 0:
                hit = self.cache.get_result(
                    programs, hw, budget, profile=request.profile,
                    spatial_reuse=request.spatial_reuse,
                    temporal_reuse=request.temporal_reuse)
                if hit is not None:
                    log.append("rung 1: exact plancache hit")
                    return respond(hit, "cache")

            # ---- rung 2: certified shape-family neighbor ----------------
            regret = (request.regret_bound
                      if request.regret_bound is not None
                      else default_regret())
            template = keying.template_signature(programs[0])
            hwd = keying.hw_digest(hw)
            bkey = f"{template}:{hwd[:16]}"
            if left() > 0:
                from . import family as family_mod
                shape = keying.shape_vector(programs[0])
                for ent in self.cache.store.nearest_k(
                        template, hwd, shape, k=3):
                    if left() <= 0:
                        break
                    res = family_mod.certified_result(
                        ent, programs, hw, regret=regret)
                    if res is not None:
                        log.extend(res.log)
                        bg = self._schedule_background(request, key, budget)
                        return respond(res, "family", background=bg)

            # ---- rung 3: deadline-bounded search ------------------------
            fall_outcome: Optional[str] = None
            if left() > 0:
                if self._no_search:
                    log.append("rung 3 shed: no search slots configured")
                    fall_outcome = "shed"
                else:
                    predicted = self._ewma.get(bkey)
                    if predicted is not None and predicted > left():
                        log.append(f"rung 3 skipped: predicted search "
                                   f"{predicted * 1e3:.1f}ms > "
                                   f"{left() * 1e3:.1f}ms left")
                    else:
                        resp = self._try_search(request, key, budget, bkey,
                                                left, log, respond)
                        if isinstance(resp, PlanResponse):
                            return resp
                        fall_outcome = resp   # None or shed/breaker_open

            # ---- rung 4: guaranteed generic fallback --------------------
            if fall_outcome is None:
                fall_outcome = "deadline" if left() <= 0 else "ok"
            return self._fallback_response(request, key, t0, deadline_ms,
                                           budget, log=log,
                                           outcome=fall_outcome)

    def _try_search(self, request: PlanRequest, key: str,
                    budget: SearchBudget, bkey: str,
                    left: Callable[[], float], log: List[str],
                    respond: Callable[..., PlanResponse]
                    ):
        """Admission gate + breaker + the search itself.  Returns a
        PlanResponse on success, else the fallback outcome tag (or None
        for plain did-not-answer)."""
        breaker = self._breaker(bkey)
        if breaker.state == "open" and not breaker.allow():
            log.append("rung 3 skipped: circuit breaker open")
            return "breaker_open"
        if not self._gate.acquire(blocking=False):
            if breaker.state == "half_open":
                breaker.force_open()         # give the trial slot back
            log.append("rung 3 shed: concurrent search limit reached")
            return "shed"
        result: Optional[PlanResult] = None
        exact = False
        target = request.hw
        t_search = self.clock()
        try:
            result, exact, target = self._do_search(request, budget, left())
        except (RuntimeError, ValueError) as e:
            log.append(f"rung 3 search infeasible: {e}")
        finally:
            self._gate.release()
        dt = self.clock() - t_search
        prev = self._ewma.get(bkey)
        self._ewma[bkey] = dt if prev is None else 0.5 * dt + 0.5 * prev
        missed = left() < 0
        if missed:
            breaker.record_miss()
            metrics.inc("planservice_breaker_miss_total")
        elif result is not None:
            breaker.record_ok()
        if result is None:
            return None
        log.append(f"rung 3: {'full' if exact else 'trimmed'}-budget search "
                   f"best {result.best.final_s * 1e6:.1f}us in "
                   f"{dt * 1e3:.1f}ms")
        bg = (False if exact
              else self._schedule_background(request, key, budget))
        return respond(result, "search",
                       outcome="deadline" if missed else "ok",
                       background=bg, target=target)

    def _do_search(self, request: PlanRequest, budget: SearchBudget,
                   remaining_s: float
                   ) -> Tuple[PlanResult, bool, HardwareModel]:
        """The actual rung-3 search.  Returns (result, exact, target_hw);
        ``exact`` means the full requested budget ran (result published
        under the exact key — no background completion needed)."""
        programs = list(request.programs)
        hw = request.hw
        if hw.is_degraded:
            # route into PR 7's degradation ladder (warmed fault pools,
            # warm-start, bounded search, submesh floor) — it publishes
            # under the degraded key itself
            from repro.runtime.replan import plan_degraded
            out = plan_degraded(
                programs, hw, cache=self.cache, budget=budget,
                latency_budget_s=(None if remaining_s == float("inf")
                                  else max(remaining_s, 1e-3)),
                cause="planservice")
            return out.result, True, out.hw
        trimmed = budget_for_deadline(budget, remaining_s)
        if trimmed == budget:
            res = plan_kernel_multi(
                programs, hw, budget=budget, profile=request.profile,
                spatial_reuse=request.spatial_reuse,
                temporal_reuse=request.temporal_reuse, cache=self.cache)
            return res, True, hw
        # trimmed budget: a different search than the exact key promises,
        # so do NOT publish under it — warm-order manually, search
        # uncached, and let background completion publish the real thing
        ordered = self.cache.order_programs(programs, hw)
        res = plan_kernel_multi(
            ordered, hw, budget=trimmed, profile=request.profile,
            spatial_reuse=request.spatial_reuse,
            temporal_reuse=request.temporal_reuse, cache=None)
        return res, False, hw

    # ------------------------------------------------------------ helpers
    def _breaker(self, bkey: str) -> _Breaker:
        with self._lock:
            br = self._breakers.get(bkey)
            if br is None:
                br = self._breakers[bkey] = _Breaker(
                    self.breaker_threshold, self.breaker_cooldown_s,
                    self.clock, key=bkey)
            return br

    def _fallback_response(self, request: PlanRequest, key: str, t0: float,
                           deadline_ms: float, budget: SearchBudget, *,
                           log: List[str], outcome: str) -> PlanResponse:
        """Rung 4, memoized per key (the fallback construction is cheap
        but not free, and overloaded callers hit it repeatedly)."""
        log = list(log)
        with self._lock:
            memo = self._fallbacks.get(key)
        if memo is None:
            try:
                from .fallback import generic_fallback_plan
                result, target = generic_fallback_plan(
                    list(request.programs), request.hw)
            except Exception as e:  # noqa: BLE001 — never raise
                result, target = None, request.hw
                log.append(f"fallback infeasible: {e}")
            memo = (result, target)
            with self._lock:
                self._fallbacks[key] = memo
        result, target = memo
        if result is None:
            outcome = "infeasible"
        bg = False
        if result is not None and key:
            bg = self._schedule_background(request, key, budget)
        if result is not None:
            log.append("rung 4: generic fallback plan")
        return PlanResponse(result=result, rung="fallback", outcome=outcome,
                            hw=target, seconds=self.clock() - t0,
                            deadline_ms=deadline_ms, key=key, log=log,
                            background=bg)

    def _schedule_background(self, request: PlanRequest, key: str,
                             budget: SearchBudget) -> bool:
        """Off-path full search publishing to the plancache; deduped per
        key so a burst of identical deadline misses starts one search."""
        want = (request.background if request.background is not None
                else background_enabled())
        if not want or self._no_search:
            return False
        with self._lock:
            if key in self._bg_keys:
                return True
            self._bg_keys.add(key)
        programs = list(request.programs)
        hw = request.hw
        rid = context.current()   # threads start with a fresh Context —
        #                           carry the request ID over explicitly

        def run() -> None:
            token = context.attach(rid)
            try:
                with self._gate:
                    if hw.is_degraded:
                        from repro.runtime.replan import plan_degraded
                        plan_degraded(programs, hw, cache=self.cache,
                                      budget=budget, latency_budget_s=None,
                                      cause="planservice_bg")
                    else:
                        plan_kernel_multi(
                            programs, hw, budget=budget,
                            profile=request.profile,
                            spatial_reuse=request.spatial_reuse,
                            temporal_reuse=request.temporal_reuse,
                            cache=self.cache)
                metrics.inc("planservice_background_total",
                            outcome="published")
            except Exception:  # noqa: BLE001 — background must die quietly
                metrics.inc("planservice_background_total", outcome="failed")
            finally:
                with self._lock:
                    self._bg_keys.discard(key)
                context.detach(token)

        th = threading.Thread(target=run, daemon=True,
                              name=f"planservice-bg-{key[:8]}")
        with self._lock:
            self._bg_threads.append(th)
        th.start()
        return True

    def _note(self, resp: Optional[PlanResponse]) -> None:
        if resp is None:
            return
        metrics.inc("planservice_requests_total", rung=resp.rung,
                    outcome=resp.outcome)
        metrics.observe("planservice_resolve_seconds", resp.seconds,
                        rung=resp.rung)
        missed = (resp.deadline_ms != float("inf")
                  and resp.seconds * 1e3 > resp.deadline_ms)
        if missed:
            metrics.inc("planservice_deadline_miss_total", rung=resp.rung)
        flightrec.record("plan_request", rung=resp.rung,
                         outcome=resp.outcome, seconds=resp.seconds,
                         deadline_ms=resp.deadline_ms, key=resp.key,
                         background=resp.background, log=resp.log)
        # SLO view: a request attains its deadline when it answered with
        # a usable plan inside the budget — regardless of which rung
        slo.note_request(ok=(resp.ok and not missed
                             and resp.outcome not in ("error",)),
                         rung=resp.rung, seconds=resp.seconds)
