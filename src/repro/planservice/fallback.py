"""Rung 4: the guaranteed generic fallback plan.

When the deadline expires (or every other rung declined), the service
still owes the caller a runnable plan.  This module builds one without
searching: take the smallest-footprint candidate program, bind it with
the natural 2D output-stationary mapping (or the flattened 1D one when
the program/mesh is not 2D), pick the first capacity-feasible memory-op
combo, and cost it.  Quality is explicitly *not* the goal — validity and
O(1) construction time are; background completion replaces the answer
with a searched plan off the request path.

On a degraded fabric the fallback targets the largest healthy
rectangular submesh (``runtime/replan.best_submesh``), the same floor
PR 7's ladder bottoms out on.
"""
from __future__ import annotations

import time
from typing import List, Sequence, Tuple

from repro.core.hw import HardwareModel
from repro.core.perfmodel import estimate
from repro.core.plan import DataflowPlan
from repro.core.planner import Candidate, PlanResult
from repro.core.program import TileProgram
from repro.core.reuse import memop_choices_with_stores
from repro.core.simulator import simulate
from repro.core.templates import _mapping_1d, _mapping_2d
from repro.plancache.validate import validate_plan


def _footprint(prog: TileProgram) -> int:
    """Double-buffered load tiles + accumulators: the residency the plan
    will need, so ascending order tries the most-likely-feasible first."""
    return sum(2 * a.tile_bytes for a in prog.loads) + \
        prog.accumulator_bytes()


def generic_fallback_plan(programs: Sequence[TileProgram],
                          hw: HardwareModel
                          ) -> Tuple[PlanResult, HardwareModel]:
    """Build the guaranteed plan.  Raises ``RuntimeError`` only when *no*
    candidate program fits the hardware at all (a genuinely infeasible
    request — the service reports it instead of inventing a plan)."""
    t0 = time.perf_counter()
    target = hw
    if hw.is_degraded and hw.disabled_cores:
        try:
            from repro.runtime.replan import best_submesh
            target = best_submesh(hw)
        except RuntimeError:
            target = hw              # no clean cut: try routing around holes
    log: List[str] = []
    for prog in sorted(programs, key=_footprint):
        if len(prog.grid_dims) >= 2 and len(target.mesh_dims) >= 2:
            mapping = _mapping_2d(prog, target)
        else:
            flat = max(prog.grid_dims, key=lambda d: d.extent).name
            mapping = _mapping_1d(prog, target, flat)
        if mapping.conflicts_with_faults(target):
            log.append(f"{prog.name}: mapping lands on disabled cores")
            continue
        try:
            combos, stores = memop_choices_with_stores(
                mapping, target, max_per_load=2, max_plans=1)
        except (RuntimeError, ValueError) as e:
            log.append(f"{prog.name}: {e}")
            continue
        if not combos:
            log.append(f"{prog.name}: no feasible memory-op combo")
            continue
        plan = DataflowPlan(mapping, combos[0], stores)
        bad = validate_plan(plan, target)
        if bad:
            log.append(f"{prog.name}: {'; '.join(bad)}")
            continue
        cost = estimate(plan, target)
        sim = simulate(plan, target)
        cand = Candidate(plan=plan, cost=cost, sim=sim, index=(0, 0, 0))
        log.append("generic_fallback")
        return PlanResult(
            kernel=prog.name, hw_name=target.name, best=cand, topk=[cand],
            n_candidates=1, n_mappings=1,
            plan_seconds=time.perf_counter() - t0, log=log), target
    raise RuntimeError(
        f"no generic fallback on {target.name}: "
        + ("; ".join(log) if log else "no candidate programs"))
