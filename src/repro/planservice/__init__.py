# Deadline-bounded plan service (DESIGN_PLANSERVICE.md).
#
# Layers:  fallback.py (rung 4: guaranteed generic plan, O(1) build)
#       -> family.py   (rung 2: shape-neighbor transplant + certification)
#       -> service.py  (the ladder, coalescing, admission gate, breaker,
#                       background completion)
from .fallback import generic_fallback_plan
from .family import certified_result, certify_plan, program_floor, \
    retarget_plan
from .service import (ENV_BG, ENV_DEADLINE, ENV_REGRET, MeshPlanResponse,
                      PlanRequest, PlanResponse, PlanService, RUNGS,
                      background_enabled, default_deadline_ms,
                      default_regret)

__all__ = [
    "PlanService", "PlanRequest", "PlanResponse", "MeshPlanResponse",
    "RUNGS", "ENV_DEADLINE", "ENV_REGRET", "ENV_BG",
    "default_deadline_ms", "default_regret", "background_enabled",
    "generic_fallback_plan",
    "certified_result", "certify_plan", "program_floor", "retarget_plan",
]
