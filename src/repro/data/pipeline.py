"""Deterministic sharded token pipeline.

Production shape: each host produces only ITS shard of the global batch
(``host_batch_slice``), deterministically from (seed, step), so any host can
be restarted at any step without coordination — the property that makes the
checkpoint-restart and elastic-rescale paths (``runtime/``) cheap.  Sources:

* ``SyntheticLM``   — Zipf-ish token stream with a fixed PRNG tree (default;
  this container has no corpus);
* ``FileTokens``    — memory-mapped token file (``.bin`` of uint16/uint32),
  strided deterministically per (step, host).

Both yield {tokens, labels} with next-token labels; the VLM/audio wrappers
add stub modality tensors per the assignment (precomputed patch / frame
embeddings).
"""
from __future__ import annotations

import dataclasses
import hashlib
from pathlib import Path
from typing import Dict, Iterator, Optional, Tuple

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab_size: int = 32000
    source: str = "synthetic"        # synthetic | file
    path: Optional[str] = None
    zipf_a: float = 1.2


def _rng_for(seed: int, step: int, host: int) -> np.random.Generator:
    key = hashlib.blake2b(f"{seed}:{step}:{host}".encode(),
                          digest_size=8).digest()
    return np.random.default_rng(int.from_bytes(key, "little"))


class SyntheticLM:
    """Zipf-distributed tokens; deterministic per (seed, step, host)."""

    def __init__(self, dcfg: DataConfig, cfg: ModelConfig):
        self.dcfg = dcfg
        self.vocab = min(cfg.vocab_size, dcfg.vocab_size)
        self.cfg = cfg

    def batch_at(self, step: int, batch: int, seq_len: int,
                 host: int = 0) -> Dict[str, np.ndarray]:
        rng = _rng_for(self.dcfg.seed, step, host)
        z = rng.zipf(self.dcfg.zipf_a, size=(batch, seq_len + 1))
        toks = (z % (self.vocab - 2)) + 1          # avoid 0 (pad)
        out = {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }
        if self.cfg.family == "vlm":
            out["patches"] = rng.standard_normal(
                (batch, self.cfg.frontend_len, self.cfg.frontend_dim)
            ).astype(np.float32) * 0.02
        if self.cfg.family == "audio":
            out["frames"] = rng.standard_normal(
                (batch, self.cfg.frontend_len, self.cfg.frontend_dim)
            ).astype(np.float32) * 0.02
        return out


class FileTokens:
    """Memory-mapped contiguous token file; window per (step, host, slot)."""

    def __init__(self, dcfg: DataConfig, cfg: ModelConfig):
        assert dcfg.path, "FileTokens needs DataConfig.path"
        raw = np.memmap(dcfg.path, dtype=np.uint16, mode="r")
        self.tokens = raw
        self.cfg = cfg
        self.dcfg = dcfg

    def batch_at(self, step: int, batch: int, seq_len: int,
                 host: int = 0) -> Dict[str, np.ndarray]:
        n = len(self.tokens) - (seq_len + 1)
        rng = _rng_for(self.dcfg.seed, step, host)
        starts = rng.integers(0, max(1, n), size=batch)
        win = np.stack([self.tokens[s:s + seq_len + 1] for s in starts])
        win = win.astype(np.int32) % self.cfg.vocab_size
        return {"tokens": win[:, :-1], "labels": win[:, 1:]}


def make_source(dcfg: DataConfig, cfg: ModelConfig):
    if dcfg.source == "file":
        return FileTokens(dcfg, cfg)
    return SyntheticLM(dcfg, cfg)


def host_batch_slice(global_batch: int, n_hosts: int, host: int
                     ) -> Tuple[int, int]:
    """[start, size) of this host's slice of the global batch."""
    per = global_batch // n_hosts
    rem = global_batch % n_hosts
    start = host * per + min(host, rem)
    size = per + (1 if host < rem else 0)
    return start, size


def batches(source, shape: ShapeConfig, *, start_step: int = 0,
            host: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield source.batch_at(step, shape.global_batch, shape.seq_len, host)
        step += 1
