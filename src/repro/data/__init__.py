from .pipeline import DataConfig, FileTokens, SyntheticLM, batches, host_batch_slice, make_source
