"""TileLoom-JAX: automatic dataflow planning for tile programs (paper
reproduction) and TPU pod sharding (deployment).  See README.md."""
__version__ = "1.0.0"
