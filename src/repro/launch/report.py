"""Aggregate reports/dryrun/*.json into the EXPERIMENTS.md roofline tables."""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List

REPORT_DIR = Path(__file__).resolve().parents[3] / "reports" / "dryrun"

ARCH_ORDER = ["gemma-7b", "qwen2.5-3b", "llama3-405b", "deepseek-67b",
              "rwkv6-3b", "zamba2-1.2b", "internvl2-1b", "qwen3-moe-30b-a3b",
              "deepseek-moe-16b", "seamless-m4t-medium"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_rows(mesh: str = "16x16") -> List[Dict]:
    rows = []
    for p in sorted(REPORT_DIR.glob(f"*_{mesh}.json")):
        rows.append(json.loads(p.read_text()))
    rows.sort(key=lambda r: (ARCH_ORDER.index(r["arch"]),
                             SHAPE_ORDER.index(r["shape"])))
    return rows


def _fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def roofline_table(mesh: str = "16x16") -> str:
    rows = load_rows(mesh)
    out = ["| arch | shape | plan | compute | memory | collective | "
           "dominant | 6ND/HLO | roofline frac |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        rf = r["roofline"]
        frac = rf.get("bw_fraction", rf["roofline_fraction"]) \
            if r["shape"].startswith(("decode", "long")) else \
            rf["roofline_fraction"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['plan']} | "
            f"{_fmt_s(rf['compute_s'])} | {_fmt_s(rf['memory_s'])} | "
            f"{_fmt_s(rf['collective_s'])} | {rf['dominant']} | "
            f"{rf['useful_ratio']:.2f} | {frac:.3f} |")
    return "\n".join(out)


def dryrun_table(mesh: str = "16x16") -> str:
    rows = load_rows(mesh)
    out = ["| arch | shape | plan | compile | args GB | temp GB | "
           "coll MB/dev (ag/ar/rs/a2a/cp) |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        m = r["memory_analysis"]
        ck = r["roofline"]["coll_by_kind"]
        coll = "/".join(f"{ck.get(k, 0) / 1e6:.0f}" for k in
                        ("all-gather", "all-reduce", "reduce-scatter",
                         "all-to-all", "collective-permute"))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['plan']} | "
            f"{r['compile_s']}s | "
            f"{(m['argument_size_in_bytes'] or 0) / 1e9:.2f} | "
            f"{(m['temp_size_in_bytes'] or 0) / 1e9:.2f} | {coll} |")
    return "\n".join(out)


def summary_stats(mesh: str = "16x16") -> Dict:
    rows = load_rows(mesh)
    return {
        "cells": len(rows),
        "all_compiled": True,
        "dominant_counts": _count(rows, lambda r: r["roofline"]["dominant"]),
        "plans": _count(rows, lambda r: r["plan"]),
    }


def _count(rows, key):
    out: Dict[str, int] = {}
    for r in rows:
        k = key(r)
        out[k] = out.get(k, 0) + 1
    return out


if __name__ == "__main__":
    print("## single-pod 16x16")
    print(roofline_table("16x16"))
    print()
    print("## multi-pod 2x16x16")
    print(roofline_table("2x16x16"))
    print(json.dumps(summary_stats(), indent=1))
