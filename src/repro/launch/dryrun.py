import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()
# ^ MUST precede any jax import: jax locks the device count on first init.
"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input-shape x mesh) cell:

    with mesh:
        lowered  = jax.jit(step, in_shardings=..., out_shardings=...) \\
                       .lower(*input_specs)
        compiled = lowered.compile()
        print(compiled.memory_analysis())    # proves it fits
        print(compiled.cost_analysis())      # FLOPs/bytes for SRoofline

Shardings come from the TileLoom mesh planner (``--plan auto``, the default)
or a named fixed plan.  Results (memory/cost/collective bytes + roofline
terms) are dumped as JSON under ``reports/dryrun/`` for EXPERIMENTS.md.

Run one cell:     python -m repro.launch.dryrun --arch qwen2.5-3b \\
                      --shape train_4k --mesh single
Run all cells:    python -m repro.launch.dryrun --all   (spawns subprocesses
                  so each cell gets a fresh XLA runtime)
"""
import argparse
import json
import math
import subprocess
import sys
import time
import traceback
from pathlib import Path

REPORT_DIR = Path(__file__).resolve().parents[3] / "reports" / "dryrun"


def _train_cfg(arch: str):
    from repro.configs.base import TrainConfig
    if arch in ("llama3-405b",):
        return TrainConfig(optimizer="adafactor", opt_state_dtype="bfloat16",
                           microbatches=64)
    if arch in ("deepseek-67b",):
        return TrainConfig(opt_state_dtype="bfloat16", microbatches=8)
    return TrainConfig(microbatches=4)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             plan_name: str = "auto", out_dir: Path = REPORT_DIR,
             *, microbatches: int = 0, grad_compression: str = "",
             remat: str = "", tag: str = "") -> dict:
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config, get_shape, cell_skip_reason
    from repro.models import build_model
    from repro.parallel import sharding as SH
    from repro.parallel.planner_bridge import plan_mesh, tileloom_view
    from repro.train import serve_step as SS, train_step as TS
    from . import roofline as RL
    from .mesh import make_production_mesh

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    skip = cell_skip_reason(cfg, shape)
    if skip:
        return {"arch": arch, "shape": shape_name, "skipped": skip}

    tcfg = _train_cfg(arch)
    if microbatches:
        import dataclasses
        tcfg = dataclasses.replace(tcfg, microbatches=microbatches)
    if grad_compression:
        import dataclasses
        tcfg = dataclasses.replace(tcfg, grad_compression=grad_compression)
    if remat:
        import dataclasses
        cfg = dataclasses.replace(cfg, remat=(remat != "off"))
    api = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = math.prod(mesh.devices.shape)

    # ---- plan selection (TileLoom step 1) --------------------------------
    ranked = plan_mesh(api, shape, tcfg, multi_pod=multi_pod)
    if plan_name == "auto":
        chosen = ranked[0]
        if not chosen.cost.feasible:
            raise RuntimeError(
                f"no feasible plan for {arch}/{shape_name}: "
                + "; ".join(f"{r.plan.name}:{r.notes}" for r in ranked))
        plan = chosen.plan
    else:
        plan = dict(SH.FIXED_PLANS, zero3=None)[plan_name]() \
            if plan_name in SH.FIXED_PLANS else \
            next(r.plan for r in ranked if r.plan.name == plan_name)

    t0 = time.perf_counter()
    is_train = shape.kind == "train"
    with mesh:
        if shape.kind == "train":
            specs = api.input_specs(shape)
            state_abs = TS.abstract_state(api, tcfg)
            jitted = TS.jit_train_step(api, tcfg, plan, mesh, specs)
            lowered = jitted.lower(state_abs, specs)
        elif shape.kind == "prefill":
            specs = api.input_specs(shape)

            def prefill_step(params, batch):
                with SH.use_plan(plan, mesh):
                    return api.logits_fn(params, batch)

            p_sh = SS.param_shardings(api, plan, mesh)
            b_sh = TS.batch_shardings(specs, plan, mesh)
            lowered = jax.jit(prefill_step, in_shardings=(p_sh, b_sh)) \
                .lower(api.abstract_params(), specs)
        else:  # decode
            specs = api.input_specs(shape)
            jitted = SS.jit_serve_step(api, plan, mesh, specs["cache"],
                                       tokens_shape=tuple(
                                           specs["tokens"].shape))
            lowered = jitted.lower(api.abstract_params(), specs["tokens"],
                                   specs["cache"])
        compiled = lowered.compile()
    compile_s = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    tokens = shape.global_batch * (shape.seq_len
                                   if shape.kind != "decode" else 1)
    mf = RL.model_flops_estimate(api.n_active_params(), tokens, is_train)
    trips = RL.trips_by_depth_for(cfg, shape.kind, tcfg.microbatches,
                                  shape.seq_len)
    report = RL.from_compiled(arch, shape_name, mesh_name, chips,
                              dict(cost) if cost else {}, hlo, mf,
                              trips_by_depth=trips)

    mem_row = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        mem_row[k] = getattr(mem, k, None)
    args_b = mem_row.get("argument_size_in_bytes") or 0
    temp_b = mem_row.get("temp_size_in_bytes") or 0
    alias_b = mem_row.get("alias_size_in_bytes") or 0
    per_device_bytes = args_b + temp_b - alias_b

    row = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "chips": chips,
        "plan": plan.name, "compile_s": round(compile_s, 2),
        "memory_analysis": mem_row,
        "per_device_bytes": per_device_bytes,
        "fits_hbm": per_device_bytes <= 16e9,
        "planner_ranking": [
            {"plan": r.plan.name, "total_s": r.cost.total_s,
             "dominant": r.cost.dominant, "feasible": r.cost.feasible,
             "hbm_gb": round(r.cost.hbm_bytes_per_chip / 1e9, 2),
             "notes": r.notes}
            for r in ranked],
        "tileloom_view": tileloom_view(plan, cfg),
        "roofline": report.row(),
    }
    # decode cells are bandwidth-bound by design: also report the structural
    # minimum HBM traffic (params + cache read once) vs the HLO traffic
    if shape.kind == "decode":
        import numpy as _np
        pbytes = sum(_np.prod(l.shape) * jnp.dtype(l.dtype).itemsize
                     for l in jax.tree.leaves(api.abstract_params()))
        cbytes = sum(_np.prod(l.shape) * jnp.dtype(l.dtype).itemsize
                     for l in jax.tree.leaves(specs["cache"]))
        row["roofline"]["min_stream_bytes"] = float(pbytes + cbytes)
        row["roofline"]["bw_fraction"] = float(
            (pbytes + cbytes) / max(report.hlo_bytes, 1.0))
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = f"_{tag}" if tag else ""
    out = out_dir / f"{arch}_{shape_name}_{mesh_name}{suffix}.json"
    out.write_text(json.dumps(row, indent=2, default=str))
    print(f"[dryrun] {arch} {shape_name} {mesh_name} plan={plan.name} "
          f"compile={compile_s:.1f}s per_device="
          f"{per_device_bytes / 1e9:.2f}GB "
          f"dominant={report.dominant} "
          f"roofline_frac={report.roofline_fraction:.3f}")
    print(f"  memory_analysis: {mem_row}")
    print(f"  cost_analysis: flops={report.hlo_flops / chips:.3e} "
          f"bytes={report.hlo_bytes / chips:.3e} (per device)")
    print(f"  collectives: { {k: f'{v/1e6:.1f}MB' for k, v in report.coll_by_kind.items() if k != '_counts' and v} }")
    return row


def run_all(meshes=("single", "multi"), archs=None, shapes=None,
            timeout: int = 1800) -> int:
    from repro.configs import cells
    failures = []
    todo = []
    for cfg, shape, _ in cells():
        if archs and cfg.name not in archs:
            continue
        if shapes and shape.name not in shapes:
            continue
        for m in meshes:
            todo.append((cfg.name, shape.name, m))
    print(f"[dryrun] {len(todo)} cells to compile")
    for arch, shp, m in todo:
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shp, "--mesh", m]
        r = subprocess.run(cmd, capture_output=True, text=True,
                           timeout=timeout)
        tail = (r.stdout + r.stderr).strip().splitlines()
        if r.returncode != 0:
            failures.append((arch, shp, m, "\n".join(tail[-15:])))
            print(f"FAIL {arch} {shp} {m}")
        else:
            for line in tail:
                if line.startswith("[dryrun]"):
                    print(line)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for arch, shp, m, msg in failures:
            print(f"--- {arch} {shp} {m}\n{msg}\n")
    else:
        print("\nALL CELLS COMPILED")
    return len(failures)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--plan", default="auto")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--grad-compression", default="")
    ap.add_argument("--remat", default="")
    ap.add_argument("--tag", default="")
    ap.add_argument("--archs", nargs="*")
    ap.add_argument("--shapes", nargs="*")
    args = ap.parse_args()
    if args.all:
        sys.exit(run_all(archs=args.archs, shapes=args.shapes))
    row = run_cell(args.arch, args.shape, args.mesh == "multi", args.plan,
                   microbatches=args.microbatches,
                   grad_compression=args.grad_compression,
                   remat=args.remat, tag=args.tag)
    if row.get("skipped"):
        print(f"[dryrun] SKIP {args.arch} {args.shape}: {row['skipped']}")


if __name__ == "__main__":
    main()
