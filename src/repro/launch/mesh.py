"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — only ``dryrun.py`` (which sets
``--xla_force_host_platform_device_count=512`` before any jax import) and real
TPU launches ever call it with the full shapes.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """A small mesh over whatever devices exist (tests / single host)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // max(1, data)))
    return jax.make_mesh((data, model), ("data", "model"))
