"""Roofline-term extraction from a compiled XLA artifact.

Three terms per (arch x shape x mesh) cell, with the assignment's hardware
constants (TPU v5e): 197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link
ICI (25 GB/s DCN for the 'pod' axis):

    compute term    = HLO_FLOPs / (chips x peak)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

``cost_analysis()`` provides FLOPs and bytes accessed; collective bytes are
NOT in cost_analysis, so we parse the optimized HLO text and sum operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op (deduplicating by instruction name).
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

PEAK_FLOPS = 197e12
HBM_GBPS = 819.0
ICI_GBPS = 50.0
DCN_GBPS = 25.0

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %ag = bf16[16,1024,512]{2,1,0} all-gather(%x), replica_groups=...
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}\s]+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(", re.M)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


# -- while-loop-aware accounting -------------------------------------------
# XLA's module-level cost_analysis and a flat scan of the HLO text count each
# while-loop *body once*, but scan-over-layers / microbatch / chunk loops
# execute their bodies many times.  The dry-run KNOWS the loop structure it
# lowered (microbatches x layers x chunks), so we reconstruct the while
# *nesting* from the HLO text and assign trip counts by nesting depth
# (``trips_by_depth``), then weight every computation by the product of its
# enclosing trips.

_WHILE_BODY_RE = re.compile(r"body=%?([\w.\-]+)")


def _split_computations(hlo_text: str) -> Tuple[Dict[str, str], Optional[str]]:
    """computation name -> body text, plus the ENTRY computation name.
    Line-based brace-depth scanner (HLO instruction lines have balanced
    braces; computation headers end with '{' at depth 0)."""
    comps: Dict[str, str] = {}
    entry = None
    current = None
    depth = 0
    buf: list = []
    head_re = re.compile(r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s*\(")
    for line in hlo_text.splitlines():
        if current is None:
            m = head_re.match(line)
            if m and line.rstrip().endswith("{"):
                current = m.group(2)
                if m.group(1):
                    entry = current
                buf = [line]
                depth = line.count("{") - line.count("}")
                if depth <= 0:
                    comps[current] = line
                    current = None
            continue
        buf.append(line)
        depth += line.count("{") - line.count("}")
        if depth <= 0:
            comps[current] = "\n".join(buf)
            current = None
    return comps, entry


def _tuple_lead_dims(comp_text: str) -> Tuple[list, list]:
    """(leading dims > 1 of every array, lengths of 1-D integer arrays) in a
    while body's parameter tuple (first line of the computation).  A 1-D
    s32/u32 array is almost always the ``jnp.arange(n)`` xs of a lax.scan —
    the strongest trip-count signal."""
    header = comp_text.split("\n", 1)[0]
    dims, iotas = [], []
    for m in _SHAPE_RE.finditer(header):
        ds = [int(d) for d in m.group(2).split(",") if d]
        if ds and ds[0] > 1:
            dims.append(ds[0])
            if len(ds) == 1 and m.group(1) in ("s32", "u32", "s64", "u64"):
                iotas.append(ds[0])
    return dims, iotas


def computation_multipliers(hlo_text: str,
                            trips_by_depth: Sequence[int] = ()
                            ) -> Dict[str, int]:
    """name -> product of enclosing while trip counts.

    Trip assignment per while body: the depth-matched provided trip if it
    appears among the body tuple's leading dims; else any provided trip that
    appears (sibling scans shift depths); else the smallest observed leading
    dim (a lax.scan body always carries an s32[n] iota or an n-stacked xs).
    Fusions / reducers called from a body inherit its multiplier.
    """
    comps, entry = _split_computations(hlo_text)
    mult: Dict[str, int] = {}
    body_of: Dict[str, list] = {name: _WHILE_BODY_RE.findall(text)
                                for name, text in comps.items()}
    provided = [int(t) for t in trips_by_depth]

    def trip_for(body_name: str, depth: int) -> int:
        dims, iotas = _tuple_lead_dims(comps.get(body_name, ""))
        if iotas:                       # explicit jnp.arange(n) xs: exact
            return min(iotas)
        if depth < len(provided) and provided[depth] in dims:
            return provided[depth]
        for p in provided:
            if p in dims:
                return p
        return min(dims) if dims else 1

    def visit(name: str, m: int, depth: int, seen):
        if name in seen:
            return
        seen = seen | {name}
        mult[name] = max(mult.get(name, 1), m)
        for child in body_of.get(name, []):
            if child in comps:
                visit(child, m * max(1, trip_for(child, depth)), depth + 1,
                      seen)

    if entry:
        visit(entry, 1, 0, frozenset())
    # computations called from while bodies (fusions, reducers) inherit the
    # caller's multiplier
    call_re = re.compile(r"(?:calls=|to_apply=|condition=)%?([\w.\-]+)")
    for _ in range(4):
        changed = False
        for name, text in comps.items():
            w = mult.get(name, 1)
            for callee in call_re.findall(text):
                if callee in comps and mult.get(callee, 1) < w:
                    mult[callee] = w
                    changed = True
        if not changed:
            break
    return mult


_DEF_RE = re.compile(r"%([\w.\-]+)\s*=\s*(\w+\[[\d,]*\])")
_PARAM_RE = re.compile(r"([\w.\-]+)\s*:\s*(\w+\[[\d,]*\])")
# the lhs operand may carry an inline type annotation depending on the HLO
# printer version: ``dot(%lhs, ...)`` or ``dot(f32[16,32]{1,0} %lhs, ...)``
_DOT_RE = re.compile(
    r"%[\w.\-]+\s*=\s*(\w+\[[\d,]*\])[^\n]*?\bdot\(\s*"
    r"(?:\w+\[[\d,]*\](?:\{[^}]*\})?\s+)?%?([\w.\-]+)"
    r"[^\n]*?lhs_contracting_dims=\{([\d,]+)\}")


def _dims(shape_text: str) -> list:
    m = _SHAPE_RE.search(shape_text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def dot_flops(hlo_text: str, trips_by_depth: Sequence[int] = ()
              ) -> Tuple[float, float]:
    """(loop-weighted, flat) total dot FLOPs, computed exactly per dot op:
    2 * prod(output dims) * prod(lhs contracting dims)."""
    comps, _ = _split_computations(hlo_text)
    mult = computation_multipliers(hlo_text, trips_by_depth)
    weighted = flat = 0.0
    for name, text in comps.items():
        shapes: Dict[str, str] = {}
        for dm in _DEF_RE.finditer(text):
            shapes.setdefault(dm.group(1), dm.group(2))
        header = text.split("\n", 1)[0]
        for pm in _PARAM_RE.finditer(header):
            shapes.setdefault(pm.group(1), pm.group(2))
        for m in _DOT_RE.finditer(text):
            out_dims = _dims(m.group(1))
            lhs = shapes.get(m.group(2))
            if lhs is None:
                continue
            lhs_dims = _dims(lhs)
            contract = 1
            for c in (int(x) for x in m.group(3).split(",") if x):
                if c < len(lhs_dims):
                    contract *= lhs_dims[c]
            f = 2.0 * math.prod(out_dims or [1]) * contract
            flat += f
            weighted += f * mult.get(name, 1)
    return weighted, flat


def loop_weighted_flops_scale(hlo_text: str,
                              trips_by_depth: Sequence[int] = ()) -> float:
    """Ratio (loop-weighted flops) / (flat flops), with per-dot exact flops
    as the weights (a count proxy mis-scales when the largest single dots —
    embedding/vocab — sit outside the loops)."""
    weighted, flat = dot_flops(hlo_text, trips_by_depth)
    return (weighted / flat) if flat else 1.0


def collective_bytes(hlo_text: str,
                     trips_by_depth: Sequence[int] = ()
                     ) -> Dict[str, float]:
    """Sum output-shape bytes of every collective op, by kind — each weighted
    by its computation's enclosing-loop trip product, so per-layer TP
    collectives inside the layer scan count n_layers (x microbatches) times
    while the once-per-step DP all-reduce counts once.  '-start' ops counted,
    '-done' skipped (async pairs share the buffer)."""
    comps, _ = _split_computations(hlo_text)
    if not comps:
        comps = {"_all": hlo_text}
    mult = computation_multipliers(hlo_text, trips_by_depth)
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for name, text in comps.items():
        w = mult.get(name, 1)
        for m in _INSTR_RE.finditer(text):
            shape_text, kind, suffix = m.group(1), m.group(2), m.group(3)
            if suffix == "-done":
                continue
            out[kind] += _shape_bytes(shape_text) * w
            counts[kind] += w
    out["_counts"] = counts  # type: ignore[assignment]
    return out


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_by_kind: Dict[str, float]
    model_flops: float
    compute_s: float = field(init=False)
    memory_s: float = field(init=False)
    collective_s: float = field(init=False)

    def __post_init__(self):
        self.compute_s = self.hlo_flops / (self.chips * PEAK_FLOPS)
        self.memory_s = self.hlo_bytes / (self.chips * HBM_GBPS * 1e9)
        self.collective_s = self.coll_bytes / (self.chips * ICI_GBPS * 1e9)

    @property
    def dominant(self) -> str:
        terms = self.terms()
        return max(terms, key=terms.get)

    def terms(self) -> Dict[str, float]:
        return {"compute": self.compute_s, "memory": self.memory_s,
                "collective": self.collective_s}

    @property
    def bound_s(self) -> float:
        return max(self.terms().values())

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful
        (catches remat recompute and padding waste).  HLO_FLOPs here are
        per-device, so scale by chips."""
        total_hlo = self.hlo_flops
        return self.model_flops / total_hlo if total_hlo else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful-compute time as a fraction of the bound (the score)."""
        useful_s = self.model_flops / (self.chips * PEAK_FLOPS)
        total = max(self.bound_s, 1e-30)
        return useful_s / total

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "coll_by_kind": {k: v for k, v in self.coll_by_kind.items()
                             if k != "_counts" and v},
            "coll_counts": self.coll_by_kind.get("_counts", {}),
        }


def model_flops_estimate(n_params_active: int, tokens: int,
                         is_train: bool) -> float:
    """MODEL_FLOPS = 6*N*D (train) or 2*N*D (inference), N = active params."""
    return (6.0 if is_train else 2.0) * n_params_active * tokens


def trips_by_depth_for(cfg, shape_kind: str, microbatches: int = 1,
                       seq_len: int = 0) -> Tuple[int, ...]:
    """The known loop-nest trip counts of the program the dry-run lowered,
    outermost first (used to re-weight XLA's body-counted-once costs)."""
    chunks = []
    if cfg.family == "ssm" and shape_kind != "decode":
        chunks = [max(1, seq_len // 16)]          # WKV chunk scan
    if cfg.family == "hybrid" and shape_kind != "decode":
        chunks = [max(1, seq_len // 32)]          # SSD chunk scan
    if cfg.family == "hybrid":
        a = cfg.attn_every or cfg.n_layers
        layers = [cfg.n_layers // a, a]
    elif cfg.family == "audio":
        layers = [max(cfg.n_layers, cfg.n_encoder_layers or 0)]
    else:
        layers = [cfg.n_layers]
    if shape_kind == "train" and microbatches > 1:
        return tuple([microbatches] + layers + chunks)
    return tuple(layers + chunks)


def from_compiled(arch: str, shape: str, mesh_name: str, chips: int,
                  cost: Dict, hlo_text: str, model_flops: float,
                  trips_by_depth: Tuple[int, ...] = ()) -> RooflineReport:
    # cost_analysis counts while bodies once; re-weight by the loop structure
    scale = loop_weighted_flops_scale(hlo_text, trips_by_depth)
    flops = float(cost.get("flops", 0.0)) * scale
    byts = float(cost.get("bytes accessed", 0.0)) * scale
    coll = collective_bytes(hlo_text, trips_by_depth)
    total_coll = sum(v for k, v in coll.items() if k != "_counts")
    return RooflineReport(arch=arch, shape=shape, mesh=mesh_name, chips=chips,
                          hlo_flops=flops * chips, hlo_bytes=byts * chips,
                          coll_bytes=total_coll * chips,
                          coll_by_kind=coll, model_flops=model_flops)
