"""End-to-end training driver.

``python -m repro.launch.train --arch qwen2.5-3b --steps 300 --reduced``
trains a reduced config on the host; on a real pod the same driver runs the
full config with the TileLoom-planned sharding.  Integrates every substrate:
planned sharding, microbatched train step, deterministic data, checkpoint
manager with auto-resume, heartbeat/straggler tracking, resilient step
retry.
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro import plancache
from repro.configs import get_config
from repro.configs.base import ShapeConfig, TrainConfig
from repro.ckpt import CheckpointManager
from repro.data import DataConfig, make_source
from repro.models import build_model
from repro.obs import metrics as obs_metrics
from repro.parallel.planner_bridge import plan_mesh
from repro.runtime import (HeartbeatRegistry, ResilientDriver,
                           StragglerTracker)
from repro.runtime.faults import env_schedule
from repro.train import train_step as TS
from .mesh import make_host_mesh


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", default="none",
                    choices=("none", "int8"))
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced config (CPU-friendly)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    tcfg = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                       warmup_steps=max(1, args.steps // 20),
                       microbatches=args.microbatches,
                       grad_compression=args.grad_compression)
    api = build_model(cfg)
    shape = ShapeConfig("cli", seq_len=args.seq, global_batch=args.batch,
                        kind="train")

    # TileLoom mesh planning (informational on a 1-device host; resolves
    # from the persistent plan registry when `repro.plancache warm` ran)
    store = plancache.get_store()
    with plancache.lookup_source(store) as probe:
        ranking = plan_mesh(api, shape, tcfg)
    print(f"[train] {cfg.name}: {api.n_params():,} params; planner ranking "
          f"({probe['source']}): "
          + ", ".join(f"{r.plan.name}({r.cost.dominant})" for r in ranking[:3]))
    store.flush_stats()

    step_fn = jax.jit(TS.make_train_step(api, tcfg))
    mgr = CheckpointManager(Path(args.ckpt_dir) / cfg.name,
                            save_every=args.save_every, keep=3)
    template = TS.abstract_state(api, tcfg)
    state, start = mgr.restore_latest(target_tree=template)
    if state is None:
        state = TS.init_state(api, tcfg, jax.random.PRNGKey(tcfg.seed))
        start = 0
        print("[train] fresh start")
    else:
        print(f"[train] resumed from step {start}")

    source = make_source(DataConfig(vocab_size=cfg.vocab_size), cfg)
    reg = HeartbeatRegistry(1)
    straggler = StragglerTracker(reg)

    # fault injection (REPRO_FAULTS): host-straggler factors scale the step
    # wall-times reported into the heartbeat registry so detection paths run
    # under injected load; hw faults apply inside the planner/benchmarks
    sched = env_schedule()
    if sched is not None:
        print(f"[train] injected faults: {sched.describe()}")

    def timed_step(state, batch):
        out_state, metrics = step_fn(state, batch)
        jax.block_until_ready(metrics["loss"])
        return out_state, metrics

    def batches(step):
        return jax.tree.map(jnp.asarray,
                            source.batch_at(step, args.batch, args.seq))

    def restore_fn():
        tree, at = mgr.restore_latest(target_tree=template)
        if tree is None:
            return TS.init_state(api, tcfg, jax.random.PRNGKey(tcfg.seed)), 0
        return tree, at

    def on_step(step, state, metrics, dt):
        if (step - 1) % args.log_every == 0 or step == args.steps:
            tok_s = args.batch * args.seq / max(dt, 1e-9)
            print(f"[train] step {step - 1:5d} "
                  f"loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} {tok_s:,.0f} tok/s")

    drv = ResilientDriver(
        timed_step, mgr, registry=reg, tracker=straggler,
        step_time_scale=(None if sched is None
                         else lambda s: sched.straggler_factor(0, s)))
    t_start = time.perf_counter()
    state, _, _ = drv.run(state, batches, start_step=start,
                          n_steps=args.steps - start,
                          restore_fn=restore_fn, on_step=on_step)
    mgr.wait()
    total = time.perf_counter() - t_start
    print(f"[train] done: {args.steps - start} steps in {total:.1f}s; "
          f"stragglers={straggler.stragglers()}")
    for ev in drv.events:
        print(f"[train] recovery: step {ev.step} {ev.kind}: {ev.detail}")
    counts = obs_metrics.counter_totals(obs_metrics.snapshot())
    if counts:
        print("[train] metrics: " + " ".join(
            f"{k}={v:g}" for k, v in sorted(counts.items())))
    dumped = obs_metrics.dump()          # honors REPRO_METRICS=<path>
    if dumped:
        print(f"[train] metrics snapshot written to {dumped}")


if __name__ == "__main__":
    main()
