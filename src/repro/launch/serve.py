"""Batched serving driver: prefill + decode loop with a sharded KV cache.

``python -m repro.launch.serve --arch qwen2.5-3b --reduced --tokens 32``
greedy-decodes a batch of synthetic prompts.  On a pod the same driver uses
the TileLoom decode plan (kv-sequence-split when kv_heads < TP, DESIGN.md).

Serving-layer observability (DESIGN_OBS.md): ``--introspect-port`` starts
a read-only HTTP endpoint (``/metrics`` Prometheus text, ``/healthz``,
``/slo``, ``/plans``, ``/tenants``) before any planning happens;
``--flightrec PATH`` (or ``REPRO_FLIGHTREC``) dumps the structured event
ring buffer at exit for ``python -m repro.obs incident PATH``.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import plancache
from repro.configs import get_config
from repro.obs import expo, flightrec, metrics, slo
from repro.configs.base import ShapeConfig, TrainConfig
from repro.data import DataConfig, make_source
from repro.models import build_model
from repro.planservice import PlanService


def _plans_view() -> dict:
    """``/plans`` payload: the registry's cross-process stats blob plus
    this serving process's live lookup counters."""
    store = plancache.get_store()
    s = store.stats
    blob = plancache.stats_blob(store)
    blob["process"] = {"hits_mem": s.hits_mem, "hits_disk": s.hits_disk,
                       "misses": s.misses, "puts": s.puts}
    return blob


def _tenants_view(state: dict) -> dict:
    """``/tenants`` payload from the live :class:`TenancyPlan` (filled in
    by :func:`_run_tenants`; empty in single-model mode)."""
    plan = state.get("plan")
    if plan is None:
        return {"mode": "model", "tenants": []}
    return {
        "hw": plan.hw.name,
        "layout_score": plan.layout_score,
        "n_layouts": plan.n_layouts,
        "free_cells": sorted(plan.free_cells()),
        "tenants": [{
            "tenant": p.tenant.name, "qos": p.tenant.qos,
            "rect": p.rect.describe(), "hw": p.hw.name, "rung": p.rung,
            "digest": p.digest, "sim_us": p.sim_s * 1e6,
        } for p in plan.placements],
        "incidents": list(state.get("incidents", [])),
    }


def _setup_observability(args) -> dict:
    """Arm the flight recorder / SLO tracker and (with
    ``--introspect-port``) start the read-only HTTP endpoint *before* any
    planning happens, so the earliest rung decisions are observable."""
    flightrec.refresh_from_env()             # REPRO_FLIGHTREC=<path>
    if args.flightrec:
        flightrec.enable(args.flightrec)
    obs = {"server": None, "plan": None, "incidents": []}
    if args.introspect_port is None and not flightrec.enabled():
        return obs
    slo.enable()                             # honors REPRO_SLO_* knobs
    if args.introspect_port is not None:
        server = expo.IntrospectionServer(port=args.introspect_port)
        server.add_provider("/plans", _plans_view)
        server.add_provider("/tenants", lambda: _tenants_view(obs))
        server.start()
        obs["server"] = server
        # the smoke lane parses this line for the bound (ephemeral) port
        print(f"[serve] introspection at {server.url} "
              f"(/metrics /healthz /slo /plans /tenants)", flush=True)
    return obs


def _finish_observability(args, obs: dict) -> None:
    if flightrec.enabled():
        path = flightrec.dump(reason="serve_done")
        if path:
            print(f"[serve] flight recorder dump: {path}")
    server = obs.get("server")
    if server is not None:
        if args.introspect_hold > 0:
            print(f"[serve] holding introspection open "
                  f"{args.introspect_hold:.1f}s at {server.url}", flush=True)
            time.sleep(args.introspect_hold)
        server.stop()


def _run_tenants(args, obs) -> None:
    """Multi-tenant serving mode (``--tenants k``): plan k concurrent
    kernel tenants onto disjoint partitions of one fabric through the
    tenancy layer, optionally inject a core kill, and *assert* the
    containment contract — the CI tenancy-smoke lane runs exactly this.
    """
    from repro.core import (block_shape_candidates, get_hw, matmul_program)
    from repro.core.planner import SearchBudget
    from repro.tenancy import (IsolationValidator, MeshPartitioner,
                               TenantAdmission, TenantRuntime, TenantSpec)

    hw = get_hw(args.tenant_hw)
    shapes = [(256, 256, 256), (128, 512, 256), (512, 128, 256),
              (256, 512, 128)]
    tenants = []
    for i in range(args.tenants):
        m, n, k = shapes[i % len(shapes)]
        progs = [matmul_program(m, n, k, bm=bm, bn=bn, bk=bk)
                 for bm, bn, bk in block_shape_candidates(m, n, k)][:6]
        qos = "guaranteed" if i % 2 == 0 else "best_effort"
        tenants.append(TenantSpec(f"tenant{i}", progs, qos=qos))

    service = PlanService()
    budget = SearchBudget(top_k=3, max_mappings=16,
                          max_plans_per_mapping=10, max_candidates=500)
    admission = TenantAdmission()
    partitioner = MeshPartitioner(plan_layouts=2)
    # admission gates each tenant's resolve deadline; the joint search
    # receives the per-tenant outcome as its budget override
    tenant_ms = {}
    for t in tenants:
        with admission.admit(t, args.plan_budget_ms) as ms:
            if ms is not None:
                tenant_ms[t.name] = ms
    plan = partitioner.plan(hw, tenants, service=service, budget=budget,
                            budget_ms=float("inf"),
                            tenant_budget_ms=tenant_ms or None)
    bad = IsolationValidator().validate(plan)
    if bad:
        raise SystemExit(f"[serve] isolation validation failed: {bad}")
    obs["plan"] = plan                   # /tenants now serves the live view
    print(f"[serve] {args.tenants} tenants on {hw.name}: "
          f"{plan.describe()}")

    if args.tenant_kill:
        core = tuple(int(v) for v in args.tenant_kill.split(","))
        runtime = TenantRuntime(plan, service=service, cache=service.cache,
                                budget=budget, partitioner=partitioner)
        ev = runtime.kill_core(core)
        obs["plan"] = runtime.plan       # containment may repartition
        obs["incidents"].append({
            "cause": ev.cause, "cell": core, "owner": ev.owner,
            "rung": ev.rung, "blast_radius": ev.blast_radius,
            "seconds": ev.seconds, "within_budget": ev.within_budget,
        })
        print(f"[serve] core_kill {core}: owner={ev.owner} rung={ev.rung} "
              f"blast_radius={ev.blast_radius} "
              f"seconds={ev.seconds * 1e3:.1f}ms "
              f"within_budget={ev.within_budget}")
        for line in ev.log:
            print(f"[serve]   {line}")
        if not ev.contained():
            raise SystemExit("[serve] CONTAINMENT VIOLATED: an untouched "
                             "tenant's plan digest changed")
        if ev.owner is not None and not ev.within_budget:
            raise SystemExit("[serve] deadline exceeded: the degraded "
                             "tenant did not resolve within its budget")
        print(f"[serve] containment ok: untouched={list(ev.untouched)} "
              f"digests unchanged")
    plancache.get_store().flush_stats()
    counts = metrics.counter_totals(metrics.snapshot())
    if counts:
        print("[serve] metrics: " + " ".join(
            f"{k}={v:g}" for k, v in sorted(counts.items())
            if k.startswith(("tenancy", "replan", "planservice"))))
    dumped = metrics.dump()              # honors REPRO_METRICS=<path>
    if dumped:
        print(f"[serve] metrics snapshot written to {dumped}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--plan-budget-ms", type=float, default=None,
                    help="plan-service deadline (default "
                         "$REPRO_PLAN_DEADLINE_MS / 10ms)")
    ap.add_argument("--tenants", type=int, default=0,
                    help="multi-tenant mode: partition the fabric for k "
                         "concurrent kernel tenants instead of serving "
                         "one model")
    ap.add_argument("--tenant-hw", default="wormhole_8x8",
                    help="fabric preset for --tenants mode")
    ap.add_argument("--tenant-kill", default="",
                    help="inject a core kill at mesh coords 'R,C' after "
                         "partitioning and assert containment")
    ap.add_argument("--introspect-port", type=int, default=None,
                    metavar="PORT",
                    help="serve read-only introspection HTTP on PORT "
                         "(0 = ephemeral; prints the bound URL): /metrics "
                         "(Prometheus text), /healthz, /slo, /plans, "
                         "/tenants")
    ap.add_argument("--introspect-hold", type=float, default=0.0,
                    metavar="SECONDS",
                    help="keep the introspection endpoint up SECONDS after "
                         "the run finishes (scrape window for smoke tests)")
    ap.add_argument("--flightrec", default="",
                    metavar="PATH",
                    help="arm the flight recorder and dump its ring buffer "
                         "to PATH at exit (same as REPRO_FLIGHTREC=PATH); "
                         "render with `python -m repro.obs incident PATH`")
    args = ap.parse_args(argv)

    obs = _setup_observability(args)
    if args.tenants > 0:
        _run_tenants(args, obs)
        _finish_observability(args, obs)
        return

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    api = build_model(cfg)
    shape = ShapeConfig("serve", seq_len=args.prompt_len + args.tokens,
                        global_batch=args.batch, kind="decode")
    # the serving loop never stalls on planning: the deadline-bounded
    # service answers from cache / family / bounded search / fallback
    service = PlanService()
    resp = service.resolve_mesh(api, shape, TrainConfig(),
                                budget_ms=args.plan_budget_ms)
    ranking = resp.ranking or []
    print(f"[serve] {cfg.name}: decode plan ranking "
          f"(rung={resp.rung} {resp.seconds * 1e3:.1f}ms): "
          + ", ".join(r.plan.name for r in ranking[:3]))
    plancache.get_store().flush_stats()

    params = api.init(jax.random.PRNGKey(0))
    source = make_source(DataConfig(vocab_size=cfg.vocab_size), cfg)
    prompts = jnp.asarray(source.batch_at(0, args.batch,
                                          args.prompt_len)["tokens"])
    max_len = args.prompt_len + args.tokens + 1
    cache = api.init_cache(cfg, args.batch, max_len)
    decode = jax.jit(api.decode_step)

    # prefill token-by-token (reduced models; a pod launcher uses the fused
    # prefill path of launch/dryrun.py's prefill_step)
    t0 = time.perf_counter()
    logits = None
    for t in range(args.prompt_len):
        logits, cache = decode(params, prompts[:, t:t + 1], cache)
    prefill_s = time.perf_counter() - t0

    out = []
    tok = jnp.argmax(logits[:, -1:, :cfg.vocab_size], axis=-1).astype(jnp.int32)
    t0 = time.perf_counter()
    for _ in range(args.tokens):
        out.append(tok)
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits[:, -1:, :cfg.vocab_size],
                         axis=-1).astype(jnp.int32)
    jax.block_until_ready(tok)
    decode_s = time.perf_counter() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"[serve] prefill {args.prompt_len} tok x{args.batch}: "
          f"{prefill_s:.2f}s; decode {args.tokens} tok x{args.batch}: "
          f"{decode_s:.2f}s ({args.tokens * args.batch / decode_s:.1f} tok/s)")
    print(f"[serve] sample generation (ids): {gen[0, :16].tolist()}")
    counts = metrics.counter_totals(metrics.snapshot())
    if counts:
        print("[serve] metrics: " + " ".join(
            f"{k}={v:g}" for k, v in sorted(counts.items())))
    dumped = metrics.dump()              # honors REPRO_METRICS=<path>
    if dumped:
        print(f"[serve] metrics snapshot written to {dumped}")
    _finish_observability(args, obs)


if __name__ == "__main__":
    main()
