"""Public jit'd wrappers around the Pallas kernels.

Responsibilities:
* pad/fit shapes to legal block multiples (largest power-of-two divisor);
* pick block shapes via the TileLoom intra-chip planner when not given
  (``core/lower_jax.py`` sizes them against the TPU df chip description);
* select interpret mode automatically off-TPU (kernels execute in Python on
  CPU for correctness validation; real deployments run the compiled Mosaic
  path).

Model code calls these through ``repro.models.layers`` with a
``kernels="pallas" | "xla"`` switch: "xla" (plain jnp, fused by XLA) is the
default for CPU smoke tests and for the dry-run (whose roofline is derived
from XLA HLO), "pallas" is the TPU fast path and the unit-test subject.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import flash_decode as _fd
from . import gemm as _gemm
from . import moe_gmm as _moe
from . import ref as ref
from . import rwkv6 as _rwkv


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret(flag: Optional[bool]) -> bool:
    return (not on_tpu()) if flag is None else flag


def fit_block(n: int, desired: int, minimum: int = 8) -> int:
    """Largest power-of-two divisor of ``n`` that is <= desired (>= minimum
    when possible)."""
    b = 1
    while b * 2 <= desired and n % (b * 2) == 0:
        b *= 2
    return max(b, min(n, 1)) if b >= 1 else 1


@functools.partial(jax.jit, static_argnames=("block", "out_dtype", "interpret"))
def _matmul_impl(a, b, block, out_dtype, interpret):
    return _gemm.gemm(a, b, block=block, out_dtype=out_dtype,
                      interpret=interpret)


def matmul(a: jax.Array, b: jax.Array, *,
           block: Optional[Tuple[int, int, int]] = None,
           out_dtype=None, interpret: Optional[bool] = None) -> jax.Array:
    """Planner-blocked GEMM.  Fits blocks to the shape when not given."""
    M, K = a.shape
    _, N = b.shape
    if block is None:
        from repro.core.lower_jax import plan_gemm_blocks
        block = plan_gemm_blocks(M, N, K, a.dtype)
    bm = fit_block(M, block[0])
    bn = fit_block(N, block[1])
    bk = fit_block(K, block[2])
    return _matmul_impl(a, b, (bm, bn, bk), out_dtype, _interpret(interpret))


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_kv",
                                             "interpret", "sm_scale"))
def _attn_impl(q, k, v, sm_scale, causal, block_q, block_kv, interpret):
    return _fa.flash_attention(q, k, v, sm_scale=sm_scale, causal=causal,
                               block_q=block_q, block_kv=block_kv,
                               interpret=interpret)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              sm_scale: Optional[float] = None, causal: bool = False,
              block_q: Optional[int] = None, block_kv: Optional[int] = None,
              interpret: Optional[bool] = None) -> jax.Array:
    """FlashAttention fwd.  q: (BH, Sq, d); k/v: (BH, Skv, d)."""
    BH, Sq, d = q.shape
    Skv = k.shape[1]
    if block_q is None or block_kv is None:
        from repro.core.lower_jax import plan_flash_blocks
        pq, pkv = plan_flash_blocks(Sq, Skv, d, q.dtype)
        block_q = block_q or pq
        block_kv = block_kv or pkv
    bq = fit_block(Sq, block_q)
    bkv = fit_block(Skv, block_kv)
    scale = sm_scale if sm_scale is not None else d ** -0.5
    return _attn_impl(q, k, v, scale, causal, bq, bkv, _interpret(interpret))


@functools.partial(jax.jit, static_argnames=("kv_splits", "block_kv",
                                             "interpret", "sm_scale"))
def _decode_impl(q, k, v, sm_scale, kv_splits, block_kv, interpret):
    m, l, acc = _fd.flash_decode_partials(q, k, v, kv_splits=kv_splits,
                                          block_kv=block_kv,
                                          sm_scale=sm_scale,
                                          interpret=interpret)
    return _fd.combine_partials(m, l, acc, out_dtype=q.dtype)


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array, *,
                 sm_scale: Optional[float] = None, kv_splits: int = 8,
                 block_kv: Optional[int] = None,
                 interpret: Optional[bool] = None) -> jax.Array:
    """Decode attention: q: (BH, 1, d) vs. k/v: (BH, Skv, d)."""
    BH, _, d = q.shape
    Skv = k.shape[1]
    splits = fit_block(Skv, kv_splits)
    split_len = Skv // splits
    bkv = fit_block(split_len, block_kv or _fd.DEFAULT_BLOCK_KV)
    scale = sm_scale if sm_scale is not None else d ** -0.5
    return _decode_impl(q, k, v, scale, splits, bkv, _interpret(interpret))


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _wkv6_impl(r, k, v, log_w, u, chunk, interpret):
    return _rwkv.wkv6(r, k, v, log_w, u, chunk=chunk, interpret=interpret)


def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, log_w: jax.Array,
         u: jax.Array, *, chunk: int = _rwkv.DEFAULT_CHUNK,
         interpret: Optional[bool] = None) -> jax.Array:
    """RWKV6 WKV scan.  r/k/v/log_w: (BH, T, d); u: (BH, d)."""
    T = r.shape[1]
    c = fit_block(T, chunk)
    return _wkv6_impl(r, k, v, log_w, u, c, _interpret(interpret))


@functools.partial(jax.jit, static_argnames=("block", "out_dtype", "interpret"))
def _gmm_impl(x, w, block, out_dtype, interpret):
    return _moe.grouped_matmul(x, w, block=block, out_dtype=out_dtype,
                               interpret=interpret)


def grouped_matmul(x: jax.Array, w: jax.Array, *,
                   block: Optional[Tuple[int, int, int]] = None,
                   out_dtype=None,
                   interpret: Optional[bool] = None) -> jax.Array:
    """Per-expert GEMM.  x: (E, cap, d_in), w: (E, d_in, d_out)."""
    E, cap, d_in = x.shape
    d_out = w.shape[-1]
    if block is None:
        from repro.core.lower_jax import plan_gemm_blocks
        block = plan_gemm_blocks(cap, d_out, d_in, x.dtype)
    bm = fit_block(cap, block[0])
    bn = fit_block(d_out, block[1])
    bk = fit_block(d_in, block[2])
    return _gmm_impl(x, w, (bm, bn, bk), out_dtype, _interpret(interpret))
