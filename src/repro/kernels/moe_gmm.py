"""Grouped (per-expert) matmul Pallas kernel for MoE layers.

Capacity-based MoE routing produces a dense (E, cap, d_in) activation tensor
(tokens gathered per expert, padded to capacity); the expert FFN is then a
batched-by-expert GEMM.  Grid = (E, cap/bm, d_out/bn, d_in/bk), contraction
innermost with an f32 VMEM accumulator — the expert axis is the outermost
grid dim so each expert's weight block streams through VMEM once per output
tile (the TileLoom temporal-reuse hoist applied inside the chip).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = (128, 128, 128)


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[0], w_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(3) == n_k - 1)
    def _store():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def grouped_matmul(x: jax.Array, w: jax.Array, *,
                   block: Tuple[int, int, int] = DEFAULT_BLOCK,
                   out_dtype: Optional[jnp.dtype] = None,
                   interpret: bool = False) -> jax.Array:
    """x: (E, cap, d_in), w: (E, d_in, d_out) -> (E, cap, d_out)."""
    E, cap, d_in = x.shape
    E2, d_in2, d_out = w.shape
    assert E == E2 and d_in == d_in2, (x.shape, w.shape)
    bm, bn, bk = block
    bm = min(bm, cap)
    bn = min(bn, d_out)
    bk = min(bk, d_in)
    assert cap % bm == 0 and d_out % bn == 0 and d_in % bk == 0, (
        f"shape {(cap, d_out, d_in)} not divisible by block {(bm, bn, bk)}")
    n_k = d_in // bk
    out_dtype = out_dtype or x.dtype
    kernel = functools.partial(_gmm_kernel, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(E, cap // bm, d_out // bn, n_k),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, bk, bn), lambda e, i, j, k: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, cap, d_out), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)
