# Pallas TPU kernels for the compute hot-spots the paper optimizes
# (GEMM and FlashAttention, paper S3.2) plus the model-zoo hot-spots the
# TileLoom planner schedules (flash-decode, RWKV6 WKV scan, MoE grouped
# matmul).  Each kernel has a pure-jnp oracle in ref.py; ops.py holds the
# jit'd public wrappers with planner-chosen BlockSpecs.
from . import ops, ref
from .flash_attention import flash_attention
from .flash_decode import combine_partials, flash_decode_partials
from .gemm import gemm
from .moe_gmm import grouped_matmul
from .rwkv6 import wkv6

__all__ = ["ops", "ref", "flash_attention", "flash_decode_partials",
           "combine_partials", "gemm", "grouped_matmul", "wkv6"]
