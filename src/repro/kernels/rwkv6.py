"""Chunked RWKV6 (Finch) WKV Pallas kernel.

RWKV6's data-dependent-decay recurrence per head (state S in R^{d x d}):

    o_t[j]   = sum_i r_t[i] * (S_{t-1}[i,j] + u[i] * k_t[i] * v_t[j])
    S_t[i,j] = w_t[i] * S_{t-1}[i,j] + k_t[i] * v_t[j]

The paper's planning insight applied here (DESIGN.md S5): there is *no
spatial reuse of K across query rows* (the recurrence serializes time), so
the TPU-native formulation is the chunked scan: grid = (batch*heads, chunks)
with the chunk axis sequential, the S state carried in VMEM scratch, and the
intra-chunk part expressed as dense matmuls for the MXU:

    decays  lw = log w, cum[t] = sum_{s<=t} lw[s]          (inclusive)
    r~[t,i] = r[t,i] * exp(cum[t,i] - lw[t,i])             (exclusive decay)
    k~[s,i] = k[s,i] * exp(-cum[s,i])
    scores  = tril(r~ @ k~^T, -1) + diag(sum_i r*u*k)
    o       = r~ @ S_in + scores @ v
    S_out   = exp(cum[C-1]) (.) S_in + (k (.) exp(cum[C-1]-cum))^T @ v

Stability: the separable score factors are offset by the per-channel chunk
midpoint decay (exact — offsets cancel in the product), keeping exponents
within f32 range for per-chunk total log-decay up to ~160.  Validated against
the token-level jnp scan oracle in ref.py.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 32


def _wkv6_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, state_ref, *,
                 chunk: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0].astype(jnp.float32)          # (C, d)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)        # log decay, <= 0
    u = u_ref[0].astype(jnp.float32)          # (1, d) bonus
    S = state_ref[...]                        # (d, d)

    cum = jnp.cumsum(lw, axis=0)              # inclusive (C, d)
    cum_excl = cum - lw
    # inter-chunk: decayed read of the carried state (factor <= 1, exact)
    r_decay = r * jnp.exp(cum_excl)           # (C, d)
    o = jax.lax.dot_general(r_decay, S, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (C, d)

    # intra-chunk pairwise scores.  score[t,s] = sum_i r*k*e^{cum_excl[t,i]
    # - cum[s,i]} is separable; a per-channel midpoint offset c_i keeps both
    # factors within f32 range (exact: offsets cancel in the product).
    c_off = 0.5 * cum[-1]                     # (d,)
    r_sc = r * jnp.exp(cum_excl - c_off[None, :])
    k_sc = k * jnp.exp(c_off[None, :] - cum)
    scores = jax.lax.dot_general(r_sc, k_sc, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (C, C)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    scores = jnp.where(t_idx > s_idx, scores, 0.0)
    diag = jnp.sum(r * u * k, axis=1)         # (C,)
    o = o + jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    o = o + diag[:, None] * v

    # state propagation to the next chunk
    decay_all = jnp.exp(cum[-1])              # (d,)
    k_carry = k * jnp.exp(cum[-1][None, :] - cum)      # (C, d)
    state_ref[...] = (S * decay_all[:, None]
                      + jax.lax.dot_general(k_carry, v, (((0,), (0,)), ((), ())),
                                            preferred_element_type=jnp.float32))
    o_ref[0] = o.astype(o_ref.dtype)


def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, log_w: jax.Array,
         u: jax.Array, *, chunk: int = DEFAULT_CHUNK,
         interpret: bool = False) -> jax.Array:
    """r/k/v/log_w: (BH, T, d); u: (BH, d) -> (BH, T, d).

    ``log_w`` is the elementwise log of the decay (<= 0).  T must be a
    multiple of ``chunk`` (ops.py pads).
    """
    BH, T, d = r.shape
    c = min(chunk, T)
    assert T % c == 0, (T, c)
    u2 = u.reshape(BH, 1, d)
    kernel = functools.partial(_wkv6_kernel, chunk=c)
    return pl.pallas_call(
        kernel,
        grid=(BH, T // c),
        in_specs=[
            pl.BlockSpec((1, c, d), lambda h, t: (h, t, 0)),
            pl.BlockSpec((1, c, d), lambda h, t: (h, t, 0)),
            pl.BlockSpec((1, c, d), lambda h, t: (h, t, 0)),
            pl.BlockSpec((1, c, d), lambda h, t: (h, t, 0)),
            pl.BlockSpec((1, 1, d), lambda h, t: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, d), lambda h, t: (h, t, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, d), r.dtype),
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
    )(r, k, v, log_w, u2)
