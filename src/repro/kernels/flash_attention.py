"""FlashAttention forward Pallas kernel (TPU target; paper workload #2).

Online-softmax tiling: grid = (batch*heads, Q-blocks, KV-blocks) with the KV
axis innermost; running max / sum / output accumulator live in VMEM scratch
that persists across the sequential KV grid iterations (the TPU "arbitrary"
grid-dimension semantics; also honoured by interpret mode).  Block shapes
``(bq, bkv)`` are chosen by the TileLoom intra-chip planner
(``core/lower_jax.py``) against the VMEM capacity of the df chip description.

Supports the non-causal variant the paper evaluates (S3.2: "we focus on the
non-causal variant") and the causal variant for the model zoo.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_KV = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  n_kv: int, sm_scale: float, causal: bool,
                  bq: int, bkv: int):
    kv_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                       # (bq, d)
    k = k_ref[0]                       # (bkv, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * sm_scale                   # (bq, bkv)

    if causal:
        q_idx = pl.program_id(1)
        q_pos = q_idx * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        k_pos = kv_idx * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_ref[...]                # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)             # (bq, bkv)
    alpha = jnp.exp(m_prev - m_new)    # rescale factor for old stats
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kv_idx == n_kv - 1)
    def _store():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)      # fully-masked rows -> zeros
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    sm_scale: Optional[float] = None,
                    causal: bool = False,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_kv: int = DEFAULT_BLOCK_KV,
                    interpret: bool = False) -> jax.Array:
    """q: (BH, Sq, d), k/v: (BH, Skv, d) -> (BH, Sq, d)."""
    BH, Sq, d = q.shape
    _, Skv, _ = k.shape
    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)
    assert Sq % bq == 0 and Skv % bkv == 0, (
        f"seq lens {(Sq, Skv)} not divisible by blocks {(bq, bkv)}")
    sm_scale = sm_scale if sm_scale is not None else d ** -0.5
    n_kv = Skv // bkv
    kernel = functools.partial(_flash_kernel, n_kv=n_kv, sm_scale=sm_scale,
                               causal=causal, bq=bq, bkv=bkv)
    return pl.pallas_call(
        kernel,
        grid=(BH, Sq // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),     # running max
            pltpu.VMEM((bq, 1), jnp.float32),     # running denom
            pltpu.VMEM((bq, d), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
