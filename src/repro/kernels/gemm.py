"""Block-tiled GEMM Pallas kernel (TPU target, validated in interpret mode).

This is the block-level program that TileLoom schedules: the planner
(``core/lower_jax.py``) picks ``(bm, bn, bk)`` against the TPU intra-chip df
description (VMEM capacity, MXU 128-alignment); this file implements one tile
program with an explicit ``pl.BlockSpec`` VMEM tiling.

Grid = (M/bm, N/bn, K/bk) with the contraction dim innermost; the output
block is revisited across the k axis and accumulated in an f32 VMEM scratch
(double-buffered pipelining of the A/B blocks is done by the Pallas/Mosaic
runtime — the same load-compute-store overlap the paper's Fig 4 models).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = (128, 128, 128)        # MXU-aligned (see core.hw.tpu_v5e_chip)


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def gemm(a: jax.Array, b: jax.Array, *,
         block: Tuple[int, int, int] = DEFAULT_BLOCK,
         out_dtype: Optional[jnp.dtype] = None,
         interpret: bool = False) -> jax.Array:
    """``a @ b`` with explicit VMEM tiling.

    a: (M, K), b: (K, N) -> (M, N).  M, N, K must be divisible by the block
    shape (the ops.py wrapper pads when they are not).
    """
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, f"contraction mismatch {K} != {K2}"
    bm, bn, bk = block
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (
        f"shape {(M, N, K)} not divisible by block {block}")
    n_k = K // bk
    out_dtype = out_dtype or a.dtype
    kernel = functools.partial(_gemm_kernel, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
