"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def gemm_ref(a: jax.Array, b: jax.Array,
             out_dtype: Optional[jnp.dtype] = None) -> jax.Array:
    out_dtype = out_dtype or a.dtype
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)).astype(out_dtype)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  sm_scale: Optional[float] = None,
                  causal: bool = False) -> jax.Array:
    """Dense softmax attention.  q: (BH, Sq, d), k/v: (BH, Skv, d)."""
    d = q.shape[-1]
    sm_scale = sm_scale if sm_scale is not None else d ** -0.5
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    if causal:
        Sq, Skv = s.shape[-2], s.shape[-1]
        qi = jnp.arange(Sq)[:, None]
        ki = jnp.arange(Skv)[None, :]
        s = jnp.where(qi >= ki, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p, v.astype(jnp.float32)).astype(q.dtype)


def decode_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
               sm_scale: Optional[float] = None) -> jax.Array:
    """Single-token decode: q: (BH, 1, d)."""
    return attention_ref(q, k, v, sm_scale=sm_scale, causal=False)


def wkv6_ref(r: jax.Array, k: jax.Array, v: jax.Array, log_w: jax.Array,
             u: jax.Array) -> jax.Array:
    """Token-level RWKV6 recurrence (the chunked kernel's oracle).

    o_t = r_t . (S_{t-1} + u (.) k_t v_t^T);  S_t = diag(w_t) S_{t-1} + k_t v_t^T
    r/k/v/log_w: (BH, T, d); u: (BH, d).
    """
    BH, T, d = r.shape
    w = jnp.exp(jnp.clip(log_w.astype(jnp.float32), -1e9, 0.0))

    def head_scan(rh, kh, vh, wh, uh):
        def step(S, inputs):
            rt, kt, vt, wt = inputs
            kv = kt[:, None] * vt[None, :]                 # (d, d)
            o = rt @ (S + uh[:, None] * kv)                # (d,)
            S = wt[:, None] * S + kv
            return S, o
        S0 = jnp.zeros((d, d), jnp.float32)
        _, o = jax.lax.scan(step, S0, (rh, kh, vh, wh))
        return o

    o = jax.vmap(head_scan)(r.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32), w, u.astype(jnp.float32))
    return o.astype(r.dtype)


def grouped_matmul_ref(x: jax.Array, w: jax.Array,
                       out_dtype: Optional[jnp.dtype] = None) -> jax.Array:
    out_dtype = out_dtype or x.dtype
    return jnp.einsum("eci,eio->eco", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(out_dtype)
