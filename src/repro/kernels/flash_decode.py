"""Flash-decode Pallas kernel: one query token vs. a long KV cache.

Decode shapes (``decode_32k``, ``long_500k``) are bandwidth-bound: a single
query attends to a KV cache of up to 512k tokens.  The TPU-native adaptation
of TileLoom's "split the reusable operand across cores" insight is to split
the *KV sequence* across the grid, compute partial (max, sum-exp, weighted-V)
statistics per split, and combine them with a log-sum-exp reduction — the
intra-chip mirror of sequence-parallel flash decoding across the mesh
(``parallel/planner_bridge.py`` plans the cross-chip version of the same
dataflow).

Grid = (batch*heads, kv_splits); each program reduces its KV strip
sequentially in VMEM-sized blocks.  Outputs are per-split partials; the
``ops.py`` wrapper performs the final combine in plain JAX (cheap:
O(splits x d)).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BLOCK_KV = 512


def _decode_kernel(q_ref, k_ref, v_ref, om_ref, ol_ref, oacc_ref, *,
                   sm_scale: float, block_kv: int, split_len: int):
    q = q_ref[0]                            # (1, d)  single query row
    n_blocks = split_len // block_kv

    def body(i, carry):
        m, l, acc = carry
        k = k_ref[0, pl.dslice(i * block_kv, block_kv), :]
        v = v_ref[0, pl.dslice(i * block_kv, block_kv), :]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale                     # (1, block_kv)
        m_new = jnp.maximum(m, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc = acc * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l, acc

    d = q.shape[-1]
    m0 = jnp.full((1, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((1, 1), jnp.float32)
    a0 = jnp.zeros((1, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, a0))
    om_ref[0, 0] = m
    ol_ref[0, 0] = l
    oacc_ref[0, 0] = acc


def flash_decode_partials(q: jax.Array, k: jax.Array, v: jax.Array, *,
                          kv_splits: int = 8,
                          block_kv: int = DEFAULT_BLOCK_KV,
                          sm_scale: float | None = None,
                          interpret: bool = False
                          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """q: (BH, 1, d); k/v: (BH, Skv, d) -> per-split (m, l, acc) partials of
    shapes (BH, splits, 1, 1), (BH, splits, 1, 1), (BH, splits, 1, d)."""
    BH, one, d = q.shape
    assert one == 1, "decode kernel takes a single query token"
    _, Skv, _ = k.shape
    assert Skv % kv_splits == 0, (Skv, kv_splits)
    split_len = Skv // kv_splits
    block = min(block_kv, split_len)
    assert split_len % block == 0
    sm_scale = sm_scale if sm_scale is not None else d ** -0.5
    kernel = functools.partial(_decode_kernel, sm_scale=sm_scale,
                               block_kv=block, split_len=split_len)
    m, l, acc = pl.pallas_call(
        kernel,
        grid=(BH, kv_splits),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda h, s: (h, 0, 0)),
            pl.BlockSpec((1, split_len, d), lambda h, s: (h, s, 0)),
            pl.BlockSpec((1, split_len, d), lambda h, s: (h, s, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, 1), lambda h, s: (h, s, 0, 0)),
            pl.BlockSpec((1, 1, 1, 1), lambda h, s: (h, s, 0, 0)),
            pl.BlockSpec((1, 1, 1, d), lambda h, s: (h, s, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, kv_splits, 1, 1), jnp.float32),
            jax.ShapeDtypeStruct((BH, kv_splits, 1, 1), jnp.float32),
            jax.ShapeDtypeStruct((BH, kv_splits, 1, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return m, l, acc


def combine_partials(m: jax.Array, l: jax.Array, acc: jax.Array,
                     out_dtype=jnp.float32) -> jax.Array:
    """Log-sum-exp combine of per-split partials -> (BH, 1, d)."""
    m_g = jnp.max(m, axis=1, keepdims=True)            # (BH, 1, 1, 1)
    scale = jnp.exp(m - m_g)                           # (BH, S, 1, 1)
    l_g = jnp.sum(l * scale, axis=1)                   # (BH, 1, 1)
    acc_g = jnp.sum(acc * scale, axis=1)               # (BH, 1, d)
    l_g = jnp.where(l_g == 0.0, 1.0, l_g)
    return (acc_g / l_g).astype(out_dtype)
