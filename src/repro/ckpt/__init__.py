from .checkpoint import latest, list_steps, load_manifest, restore, save
from .manager import CheckpointManager
