"""Checkpoint manager: retention, cadence, async save, auto-resume.

The restart contract at cluster scale: a job killed at ANY point resumes from
``manager.restore_latest()`` with at most ``save_every`` steps of lost work;
the data pipeline is deterministic in (seed, step) so no data state needs
saving.  Async saves overlap the (host-side) serialization with the next
training steps — the device arrays are snapshotted (device_get) before the
background thread starts writing.
"""
from __future__ import annotations

import threading
from pathlib import Path
from typing import Any, Dict, Optional

import jax

from . import checkpoint as C


class CheckpointManager:
    def __init__(self, directory: str | Path, *, save_every: int = 100,
                 keep: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.save_every = save_every
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._failures = 0

    # -- save ---------------------------------------------------------------
    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.save_every == 0

    def save(self, tree, step: int, extra: Optional[Dict] = None,
             block: bool = False) -> None:
        self.wait()                                  # one in-flight save max
        snapshot = jax.tree.map(lambda x: jax.device_get(x), tree)

        def _do():
            try:
                C.save(snapshot, self.dir, step=step, extra=extra)
                self._gc()
            except Exception:                        # pragma: no cover
                self._failures += 1

        if self.async_save and not block:
            self._thread = threading.Thread(target=_do, daemon=True)
            self._thread.start()
        else:
            _do()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = C.list_steps(self.dir)
        for s in steps[:-self.keep]:
            import shutil
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -- restore -------------------------------------------------------------
    def restore_latest(self, target_tree=None, shardings=None):
        """(tree, step) from the newest checkpoint, or (None, 0)."""
        path = C.latest(self.dir)
        if path is None:
            return None, 0
        tree, manifest = C.restore(path, target_tree, shardings)
        return tree, int(manifest["step"])
