"""Sharded checkpointing (pure JAX + numpy; no orbax offline).

Format: one directory per step containing

* ``manifest.json``   — pytree structure, leaf shapes/dtypes, step, plan name,
                        mesh shape, save wall-time, framework version;
* ``shard_<k>.npz``   — leaf arrays, chunked so no single file exceeds
                        ``max_shard_bytes`` (object-store friendly).

Durability: writes go to ``<dir>.tmp`` and are atomically renamed — a crash
mid-save never corrupts the latest checkpoint (the restore path simply sees
the previous step).  On multi-host deployments each host writes only the
addressable shards of its devices; here (single host) we save fully-gathered
arrays, which keeps restore trivially elastic: a checkpoint taken on a 256-
chip mesh restores onto 512 chips (or 8) by resharding at load
(``runtime/elastic.py``).
"""
from __future__ import annotations

import json
import shutil
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

FORMAT_VERSION = 2


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(tree, directory: str | Path, *, step: int,
         extra: Optional[Dict] = None,
         max_shard_bytes: int = 2 << 30) -> Path:
    """Atomically save a pytree.  Returns the final directory."""
    directory = Path(directory)
    final = directory / f"step_{step:08d}"
    tmp = directory / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves = _flatten_with_paths(tree)
    manifest: Dict[str, Any] = {
        "format": FORMAT_VERSION, "step": step,
        "saved_at": time.time(), "extra": extra or {},
        "leaves": {}, "shards": [],
    }
    shard: Dict[str, np.ndarray] = {}
    shard_bytes = 0
    shard_idx = 0

    def flush():
        nonlocal shard, shard_bytes, shard_idx
        if not shard:
            return
        name = f"shard_{shard_idx:05d}.npz"
        np.savez(tmp / name, **shard)
        manifest["shards"].append(name)
        shard = {}
        shard_bytes = 0
        shard_idx += 1

    for key, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        # npz keys cannot contain '/', escape deterministically
        safe = key.replace("/", "__")
        manifest["leaves"][key] = {
            "shape": list(arr.shape), "dtype": str(arr.dtype),
            "shard": shard_idx, "npz_key": safe,
        }
        shard[safe] = arr
        shard_bytes += arr.nbytes
        if shard_bytes >= max_shard_bytes:
            flush()
    flush()
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                      # atomic publish
    return final


def load_manifest(ckpt_dir: str | Path) -> Dict:
    return json.loads((Path(ckpt_dir) / "manifest.json").read_text())


def restore(ckpt_dir: str | Path, target_tree=None,
            shardings=None) -> Tuple[Any, Dict]:
    """Restore a pytree.  With ``target_tree`` (a pytree of
    ShapeDtypeStructs or arrays) the stored leaves are mapped back into that
    structure; with ``shardings`` (matching pytree of NamedShardings) each
    leaf is placed sharded — this is the elastic-rescale path: the mesh at
    restore time may differ from the mesh at save time."""
    ckpt_dir = Path(ckpt_dir)
    manifest = load_manifest(ckpt_dir)
    buf: Dict[str, np.ndarray] = {}
    for name in manifest["shards"]:
        with np.load(ckpt_dir / name) as z:
            for k in z.files:
                buf[k] = z[k]

    by_key = {key: buf[meta["npz_key"]]
              for key, meta in manifest["leaves"].items()}
    if target_tree is None:
        return by_key, manifest

    flat, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    sh_flat = None
    if shardings is not None:
        sh_flat = jax.tree_util.tree_flatten(
            shardings, is_leaf=lambda x: x is None)[0]
    leaves = []
    for i, (path, leaf) in enumerate(flat):
        key = "/".join(_path_str(p) for p in path)
        if key not in by_key:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = by_key[key]
        want_shape = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"target {want_shape}")
        if sh_flat is not None and sh_flat[i] is not None:
            leaves.append(jax.device_put(arr, sh_flat[i]))
        else:
            leaves.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest


def list_steps(directory: str | Path) -> List[int]:
    directory = Path(directory)
    if not directory.exists():
        return []
    steps = []
    for p in directory.iterdir():
        if p.is_dir() and p.name.startswith("step_"):
            try:
                steps.append(int(p.name.split("_")[1]))
            except ValueError:
                continue
    return sorted(steps)


def latest(directory: str | Path) -> Optional[Path]:
    steps = list_steps(directory)
    if not steps:
        return None
    return Path(directory) / f"step_{steps[-1]:08d}"
