"""Parameter-spec infrastructure for the model zoo.

Every module declares its parameters once as a nested dict of
:class:`LeafSpec` (shape + init + *logical sharding axes*); from that single
source of truth we derive:

* ``materialize(rng, spec)``    — real initialized params (smoke tests/training)
* ``abstract(spec)``            — ShapeDtypeStructs (dry-run: **no allocation**)
* ``axes_of(spec)``             — a matching pytree of logical-axis tuples that
                                  ``parallel/sharding.py`` maps onto the mesh
* ``count_params(spec)``        — exact parameter counts for the roofline's
                                  MODEL_FLOPS = 6*N*D term.

Logical axis vocabulary (mapped to mesh axes by a ShardingPlan):
``batch seq embed q_heads kv_heads head_dim ffn vocab experts layers conv
state frames patches``.  ``layers`` is the stacked scan dimension.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Axes = Tuple[Optional[str], ...]


@dataclass(frozen=True)
class LeafSpec:
    shape: Tuple[int, ...]
    axes: Axes
    init: str = "normal"          # "normal" | "zeros" | "ones" | "scaled"
    scale: float = 1.0
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_leaf(rng: jax.Array, spec: LeafSpec) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "normal":
        # fan-in = first non-stacked dim (stacked "layers" dims are batch-like)
        dims = [s for s, a in zip(spec.shape, spec.axes) if a != "layers"]
        fan_in = dims[0] if len(dims) >= 2 else max(dims[-1] if dims else 1, 1)
        std = spec.scale / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(rng, spec.shape, jnp.float32) * std
                ).astype(spec.dtype)
    if spec.init == "scaled":
        return (jax.random.normal(rng, spec.shape, jnp.float32) * spec.scale
                ).astype(spec.dtype)
    raise ValueError(spec.init)


def is_leaf_spec(x) -> bool:
    return isinstance(x, LeafSpec)


def materialize(rng: jax.Array, spec) -> Any:
    leaves, treedef = jax.tree.flatten(spec, is_leaf=is_leaf_spec)
    rngs = jax.random.split(rng, len(leaves))
    vals = [_init_leaf(r, s) for r, s in zip(rngs, leaves)]
    return jax.tree.unflatten(treedef, vals)


def abstract(spec) -> Any:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), spec,
        is_leaf=is_leaf_spec)


def axes_of(spec) -> Any:
    return jax.tree.map(lambda s: s.axes, spec, is_leaf=is_leaf_spec)


def count_params(spec) -> int:
    leaves = jax.tree.leaves(spec, is_leaf=is_leaf_spec)
    return sum(math.prod(s.shape) for s in leaves)


def cast_spec_dtype(spec, dtype) -> Any:
    return jax.tree.map(
        lambda s: LeafSpec(s.shape, s.axes, s.init, s.scale, dtype), spec,
        is_leaf=is_leaf_spec)


def stack_specs(spec, n: int, axis_name: str = "layers") -> Any:
    """Prepend a stacked (scan) dimension to every leaf."""
    return jax.tree.map(
        lambda s: LeafSpec((n,) + s.shape, (axis_name,) + s.axes, s.init,
                           s.scale, s.dtype),
        spec, is_leaf=is_leaf_spec)
