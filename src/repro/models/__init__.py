# Model zoo: the 10 assigned architectures as composable JAX modules.
from .api import ModelAPI, build_model

__all__ = ["ModelAPI", "build_model"]
