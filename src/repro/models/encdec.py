"""seamless-m4t-medium backbone: encoder-decoder transformer with a stubbed
audio frontend.

Per the assignment, the modality frontend is a STUB: ``input_specs()``
provides precomputed fbank-frame embeddings (B, F, frontend_dim); a linear
adapter projects them into the encoder width.  Encoder blocks are
bidirectional; decoder blocks are causal self-attention + cross-attention to
the encoder memory + MLP.  Decode shapes exercise the *decoder* with a KV
cache; cross-attention K/V are projected once per request and cached.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel.sharding import constrain
from . import layers as L
from .param import LeafSpec, stack_specs

Params = Dict[str, Any]


def enc_block_spec(cfg: ModelConfig) -> Params:
    return {
        "attn_norm": L.rmsnorm_spec(cfg.d_model),
        "attn": L.attention_spec(cfg),
        "mlp_norm": L.rmsnorm_spec(cfg.d_model),
        "mlp": L.mlp_spec(cfg),
    }


def dec_block_spec(cfg: ModelConfig) -> Params:
    return {
        "self_norm": L.rmsnorm_spec(cfg.d_model),
        "self_attn": L.attention_spec(cfg),
        "cross_norm": L.rmsnorm_spec(cfg.d_model),
        "cross_attn": L.attention_spec(cfg),
        "mlp_norm": L.rmsnorm_spec(cfg.d_model),
        "mlp": L.mlp_spec(cfg),
    }


def encdec_spec(cfg: ModelConfig) -> Params:
    n_enc = cfg.n_encoder_layers or cfg.n_layers
    return {
        "frontend": {
            "w": LeafSpec((cfg.frontend_dim, cfg.d_model), ("frames", "embed")),
            "b": LeafSpec((cfg.d_model,), ("embed",), init="zeros"),
        },
        "embed": L.embedding_spec(cfg),                 # decoder text embed
        "enc_blocks": stack_specs(enc_block_spec(cfg), n_enc),
        "enc_norm": L.rmsnorm_spec(cfg.d_model),
        "dec_blocks": stack_specs(dec_block_spec(cfg), cfg.n_layers),
        "dec_norm": L.rmsnorm_spec(cfg.d_model),
        "lm_head": L.lm_head_spec(cfg),
    }


def encode(params: Params, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    """frames: (B, F, frontend_dim) -> encoder memory (B, F, d)."""
    dt = L.cdtype(cfg)
    x = frames.astype(dt) @ params["frontend"]["w"].astype(dt) \
        + params["frontend"]["b"].astype(dt)
    x = constrain(x, ("batch", "seq", "embed"))

    def body(h, p):
        hn = L.rmsnorm(p["attn_norm"], h, cfg.norm_eps)
        o, _ = L.attention(p["attn"], hn, cfg, causal=False)
        h = h + o
        hn = L.rmsnorm(p["mlp_norm"], h, cfg.norm_eps)
        return h + L.mlp(p["mlp"], hn, cfg), None

    if cfg.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _dec_block(p: Params, x: jax.Array, memory: jax.Array, cfg: ModelConfig,
               *, kv_cache=None, cache_index=None, cross_kv=None):
    hn = L.rmsnorm(p["self_norm"], x, cfg.norm_eps)
    o, new_cache = L.attention(p["self_attn"], hn, cfg, causal=True,
                               kv_cache=kv_cache, cache_index=cache_index)
    x = x + o
    hn = L.rmsnorm(p["cross_norm"], x, cfg.norm_eps)
    if cross_kv is not None:
        o, _ = L.attention(p["cross_attn"], hn, cfg, precomputed_kv=cross_kv)
    else:
        o, _ = L.attention(p["cross_attn"], hn, cfg, kv_input=memory,
                           causal=False)
    x = x + o
    hn = L.rmsnorm(p["mlp_norm"], x, cfg.norm_eps)
    return x + L.mlp(p["mlp"], hn, cfg), new_cache


def decode(params: Params, tokens: jax.Array, memory: jax.Array,
           cfg: ModelConfig) -> jax.Array:
    x = L.embed(params["embed"], tokens, cfg)

    def body(h, p):
        h2, _ = _dec_block(p, h, memory, cfg)
        return h2, None

    if cfg.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = L.rmsnorm(params["dec_norm"], x, cfg.norm_eps)
    return L.lm_head(params.get("lm_head", {}), x, cfg,
                     embed_params=params["embed"])


def forward(params: Params, frames: jax.Array, tokens: jax.Array,
            cfg: ModelConfig) -> jax.Array:
    memory = encode(params, frames, cfg)
    return decode(params, tokens, memory, cfg)


def loss_fn(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig):
    logits = forward(params, batch["frames"], batch["tokens"], cfg)
    loss = L.softmax_xent(logits, batch["labels"])
    return loss, {"loss": loss}


# ----------------------------------------------------------------- serving
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, memory_len: Optional[int] = None
               ) -> Dict[str, jax.Array]:
    ml = memory_len or cfg.frontend_len or 1024
    Ld = cfg.n_layers
    return {
        "k": jnp.zeros((Ld, batch, max_len, cfg.n_kv_heads, cfg.head_dim_),
                       dtype),
        "v": jnp.zeros((Ld, batch, max_len, cfg.n_kv_heads, cfg.head_dim_),
                       dtype),
        "cross_k": jnp.zeros((Ld, batch, ml, cfg.n_kv_heads, cfg.head_dim_),
                             dtype),
        "cross_v": jnp.zeros((Ld, batch, ml, cfg.n_kv_heads, cfg.head_dim_),
                             dtype),
        "index": jnp.zeros((), jnp.int32),
    }


def cache_logical_axes() -> Dict[str, Tuple[Optional[str], ...]]:
    ax = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
    return {"k": ax, "v": ax, "cross_k": ax, "cross_v": ax, "index": ()}


def prepare_cross(params: Params, memory: jax.Array, cfg: ModelConfig,
                  cache: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """Project the encoder memory into per-layer cross K/V once per request."""
    def body(_, p):
        k = jnp.einsum("bsd,dhk->bshk", memory,
                       p["cross_attn"]["wk"].astype(memory.dtype))
        v = jnp.einsum("bsd,dhk->bshk", memory,
                       p["cross_attn"]["wv"].astype(memory.dtype))
        return None, (k, v)

    _, (ck, cv) = jax.lax.scan(body, None, params["dec_blocks"])
    out = dict(cache)
    out["cross_k"] = ck.astype(cache["cross_k"].dtype)
    out["cross_v"] = cv.astype(cache["cross_v"].dtype)
    return out


def decode_step(params: Params, tokens: jax.Array,
                cache: Dict[str, jax.Array], cfg: ModelConfig):
    x = L.embed(params["embed"], tokens, cfg)
    idx = cache["index"]

    def body(h, xs):
        p, ck, cv, xk, xv = xs
        h2, new_kv = _dec_block(p, h, None, cfg, kv_cache=(ck, cv),
                                cache_index=idx, cross_kv=(xk, xv))
        return h2, new_kv

    x, (new_k, new_v) = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    x = L.rmsnorm(params["dec_norm"], x, cfg.norm_eps)
    logits = L.lm_head(params.get("lm_head", {}), x, cfg,
                       embed_params=params["embed"])
    new_cache = dict(cache)
    new_cache.update({"k": new_k, "v": new_v,
                      "index": idx + tokens.shape[1]})
    return logits, new_cache
