"""RWKV6 "Finch" (attention-free, data-dependent decay) — rwkv6-3b.

Block = time-mix (token shift -> r/k/v/g projections + the RWKV6 signature
*data-dependent decay* ``w = exp(-exp(w0 + tanh(x A) B))`` via a LoRA -> WKV
linear-recurrence core -> group-norm -> gated output) followed by channel-mix
(token shift -> squared-ReLU FFN gated by sigmoid receptance).

The WKV core runs chunked (``kernels.rwkv6`` on the pallas path; an identical
jnp chunk-scan on the xla path) — O(T) time, O(d^2) state, which is what makes
``long_500k`` decode eligible (DESIGN.md S5).  Decode carries the per-layer
state (S, shift buffers) instead of a KV cache.

Simplification vs. the released checkpoints (documented): token-shift
interpolation uses per-channel static mixes (RWKV5-style) rather than the full
5-way data-dependent lerp; the decay LoRA — the paper-relevant part — is kept
faithful.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel.sharding import constrain
from . import layers as L
from .param import LeafSpec, stack_specs

Params = Dict[str, Any]
LORA_DIM = 64
# chunk x decay-floor must stay below log(f32_max)/2 ~ 44 per side:
# 16 * 4 / 2 = 32 -> every pairwise score exponent <= 64 < 88 (finite).
WKV_CHUNK = 16


def _head_dim(cfg: ModelConfig) -> int:
    return cfg.head_dim or 64


def _n_heads(cfg: ModelConfig) -> int:
    return cfg.d_model // _head_dim(cfg)


def time_mix_spec(cfg: ModelConfig) -> Params:
    d = cfg.d_model
    H, hd = _n_heads(cfg), _head_dim(cfg)
    lora = min(LORA_DIM, d)
    return {
        "mix_r": LeafSpec((d,), ("embed",), init="zeros"),
        "mix_k": LeafSpec((d,), ("embed",), init="zeros"),
        "mix_v": LeafSpec((d,), ("embed",), init="zeros"),
        "mix_w": LeafSpec((d,), ("embed",), init="zeros"),
        "mix_g": LeafSpec((d,), ("embed",), init="zeros"),
        "wr": LeafSpec((d, d), ("embed", "q_heads")),
        "wk": LeafSpec((d, d), ("embed", "q_heads")),
        "wv": LeafSpec((d, d), ("embed", "q_heads")),
        "wg": LeafSpec((d, d), ("embed", "q_heads")),
        "wo": LeafSpec((d, d), ("q_heads", "embed")),
        # data-dependent decay LoRA (RWKV6 signature)
        "w0": LeafSpec((d,), ("embed",), init="scaled", scale=0.5),
        "wA": LeafSpec((d, lora), ("embed", None)),
        "wB": LeafSpec((lora, d), (None, "embed")),
        "u": LeafSpec((H, hd), ("q_heads", "head_dim"), init="scaled",
                      scale=0.5),
        "ln_x": LeafSpec((d,), ("embed",), init="ones"),
    }


def channel_mix_spec(cfg: ModelConfig) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mix_k": LeafSpec((d,), ("embed",), init="zeros"),
        "mix_r": LeafSpec((d,), ("embed",), init="zeros"),
        "wk": LeafSpec((d, f), ("embed", "ffn")),
        "wv": LeafSpec((f, d), ("ffn", "embed")),
        "wr": LeafSpec((d, d), ("embed", "q_heads")),
    }


def block_spec(cfg: ModelConfig) -> Params:
    return {
        "ln1": L.rmsnorm_spec(cfg.d_model),
        "tm": time_mix_spec(cfg),
        "ln2": L.rmsnorm_spec(cfg.d_model),
        "cm": channel_mix_spec(cfg),
    }


def rwkv6_spec(cfg: ModelConfig) -> Params:
    return {
        "embed": L.embedding_spec(cfg),
        "blocks": stack_specs(block_spec(cfg), cfg.n_layers),
        "final_norm": L.rmsnorm_spec(cfg.d_model),
        "lm_head": L.lm_head_spec(cfg),
    }


# ------------------------------------------------------------- WKV core
def wkv6_chunked_jnp(r, k, v, log_w, u, chunk: int = WKV_CHUNK) -> jax.Array:
    """jnp mirror of the pallas kernel (same chunked math).  Shapes as in
    kernels.rwkv6.wkv6: r/k/v/log_w (BH, T, d); u (BH, d)."""
    BH, T, d = r.shape
    c = min(chunk, T)
    assert T % c == 0
    n = T // c
    rc = r.reshape(BH, n, c, d).astype(jnp.float32)
    kc = k.reshape(BH, n, c, d).astype(jnp.float32)
    vc = v.reshape(BH, n, c, d).astype(jnp.float32)
    lw = log_w.reshape(BH, n, c, d).astype(jnp.float32)
    uu = u.astype(jnp.float32)

    t_idx = jnp.arange(c)[:, None]
    s_idx = jnp.arange(c)[None, :]
    mask = (t_idx > s_idx).astype(jnp.float32)

    def chunk_step(S, xs):
        rr, kk, vv, ww = xs                      # (BH, c, d)
        cum = jnp.cumsum(ww, axis=1)
        cum_excl = cum - ww
        r_decay = rr * jnp.exp(cum_excl)
        o = jnp.einsum("bcd,bde->bce", r_decay, S)
        c_off = 0.5 * cum[:, -1]
        r_sc = rr * jnp.exp(cum_excl - c_off[:, None, :])
        k_sc = kk * jnp.exp(c_off[:, None, :] - cum)
        scores = jnp.einsum("btd,bsd->bts", r_sc, k_sc) * mask
        diag = jnp.sum(rr * uu[:, None, :] * kk, axis=-1)
        o = o + jnp.einsum("bts,bsd->btd", scores, vv) + diag[..., None] * vv
        decay_all = jnp.exp(cum[:, -1])
        k_carry = kk * jnp.exp(cum[:, -1][:, None, :] - cum)
        S = S * decay_all[:, :, None] + jnp.einsum("bcd,bce->bde", k_carry, vv)
        return S, o

    S0 = jnp.zeros((BH, d, d), jnp.float32)
    _, o = jax.lax.scan(chunk_step, S0,
                        (rc.transpose(1, 0, 2, 3), kc.transpose(1, 0, 2, 3),
                         vc.transpose(1, 0, 2, 3), lw.transpose(1, 0, 2, 3)))
    return o.transpose(1, 0, 2, 3).reshape(BH, T, d).astype(r.dtype)


def _token_shift(x: jax.Array, prev: Optional[jax.Array] = None) -> jax.Array:
    """Shift sequence right by one; ``prev`` supplies the carry for decode."""
    if prev is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([prev[:, None, :].astype(x.dtype), x[:, :-1]],
                           axis=1)


def time_mix(p: Params, x: jax.Array, cfg: ModelConfig, *,
             shift_prev=None, state=None):
    """Returns (out, (new_shift, new_state)).  ``state``: (B,H,hd,hd) for
    single-token decode; None for chunked training/prefill."""
    B, T, d = x.shape
    H, hd = _n_heads(cfg), _head_dim(cfg)
    xp = _token_shift(x, shift_prev)

    def mixed(name):
        mu = p[f"mix_{name}"].astype(x.dtype)
        return x + (xp - x) * mu

    xr, xk, xv, xw, xg = (mixed(n) for n in "rkvwg")
    r = xr @ p["wr"].astype(x.dtype)
    k = xk @ p["wk"].astype(x.dtype)
    v = xv @ p["wv"].astype(x.dtype)
    g = xg @ p["wg"].astype(x.dtype)
    lw = -jnp.exp(p["w0"].astype(jnp.float32)
                  + jnp.tanh(xw.astype(jnp.float32) @ p["wA"].astype(jnp.float32))
                  @ p["wB"].astype(jnp.float32))
    # decay floor: e^-4 per step ~ full forget within 2 steps; guarantees the
    # chunked kernels' midpoint-offset factors stay in f32 range
    # (chunk 16 * 4 = 64 < log(f32_max) ~ 88 pairwise).  Applied at the source so
    # the pallas kernel, the jnp chunk scan, and the decode recurrence all see
    # identical decays.
    lw = jnp.maximum(lw, -4.0)

    def to_heads(t):                    # (B,T,d) -> (B*H, T, hd)
        return (t.reshape(B, T, H, hd).transpose(0, 2, 1, 3)
                .reshape(B * H, T, hd))

    u = jnp.broadcast_to(p["u"].astype(jnp.float32)[None], (B, H, hd)
                         ).reshape(B * H, hd)
    if state is None:
        if cfg.kernels == "pallas":
            from repro.kernels import ops
            o = ops.wkv6(to_heads(r), to_heads(k), to_heads(v),
                         to_heads(lw.astype(x.dtype)), u.astype(x.dtype),
                         chunk=WKV_CHUNK)
        else:
            o = wkv6_chunked_jnp(to_heads(r), to_heads(k), to_heads(v),
                                 to_heads(lw), u)
        new_state = None
    else:
        # single-token recurrence (decode): T == 1
        rh = to_heads(r)[:, 0].astype(jnp.float32)      # (BH, hd)
        kh = to_heads(k)[:, 0].astype(jnp.float32)
        vh = to_heads(v)[:, 0].astype(jnp.float32)
        wh = jnp.exp(to_heads(lw)[:, 0])
        S = state.reshape(B * H, hd, hd)
        kv = kh[:, :, None] * vh[:, None, :]
        o = jnp.einsum("bi,bij->bj", rh, S + u[:, :, None] * kv)[:, None, :]
        new_state = (wh[:, :, None] * S + kv).reshape(B, H, hd, hd)
        o = o.astype(x.dtype)
    o = (o.reshape(B, H, T, hd).transpose(0, 2, 1, 3).reshape(B, T, d))
    # per-head group norm
    oh = o.reshape(B, T, H, hd).astype(jnp.float32)
    mean = jnp.mean(oh, axis=-1, keepdims=True)
    var = jnp.var(oh, axis=-1, keepdims=True)
    oh = (oh - mean) * jax.lax.rsqrt(var + 64e-5)
    o = (oh.reshape(B, T, d) * p["ln_x"].astype(jnp.float32)).astype(x.dtype)
    o = o * jax.nn.silu(g)
    out = o @ p["wo"].astype(x.dtype)
    return constrain(out, ("batch", "seq", "embed")), (x[:, -1], new_state)


def channel_mix(p: Params, x: jax.Array, cfg: ModelConfig, *, shift_prev=None):
    xp = _token_shift(x, shift_prev)
    xk = x + (xp - x) * p["mix_k"].astype(x.dtype)
    xr = x + (xp - x) * p["mix_r"].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(xk @ p["wk"].astype(x.dtype)))
    kk = constrain(kk, ("batch", "seq", "ffn"))
    out = jax.nn.sigmoid(xr @ p["wr"].astype(x.dtype)) \
        * (kk @ p["wv"].astype(x.dtype))
    return constrain(out, ("batch", "seq", "embed")), x[:, -1]


def block_apply(p: Params, x: jax.Array, cfg: ModelConfig, *,
                shift_tm=None, state=None, shift_cm=None):
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    o, (new_shift_tm, new_state) = time_mix(p["tm"], h, cfg,
                                            shift_prev=shift_tm, state=state)
    x = x + o
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    o, new_shift_cm = channel_mix(p["cm"], h, cfg, shift_prev=shift_cm)
    return x + o, (new_shift_tm, new_state, new_shift_cm)


# ------------------------------------------------------------------- model
def forward(params: Params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = L.embed(params["embed"], tokens, cfg)

    def body(h, layer_params):
        h2, _ = block_apply(layer_params, h, cfg)
        return h2, None

    if cfg.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["blocks"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return L.lm_head(params.get("lm_head", {}), x, cfg,
                     embed_params=params["embed"])


def loss_fn(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig):
    logits = forward(params, batch["tokens"], cfg)
    loss = L.softmax_xent(logits, batch["labels"])
    return loss, {"loss": loss}


# ----------------------------------------------------------------- serving
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    H, hd = _n_heads(cfg), _head_dim(cfg)
    Lh = cfg.n_layers
    cdt = jnp.dtype(cfg.compute_dtype)
    return {
        "state": jnp.zeros((Lh, batch, H, hd, hd), jnp.float32),
        "shift_tm": jnp.zeros((Lh, batch, cfg.d_model), cdt),
        "shift_cm": jnp.zeros((Lh, batch, cfg.d_model), cdt),
        "index": jnp.zeros((), jnp.int32),
    }


def cache_logical_axes() -> Dict[str, Tuple[Optional[str], ...]]:
    return {
        "state": ("layers", "batch", "q_heads", "head_dim", None),
        "shift_tm": ("layers", "batch", "embed"),
        "shift_cm": ("layers", "batch", "embed"),
        "index": (),
    }


def decode_step(params: Params, tokens: jax.Array,
                cache: Dict[str, jax.Array], cfg: ModelConfig):
    """O(1)-per-token decode: no KV cache, just the recurrent state."""
    x = L.embed(params["embed"], tokens, cfg)

    def body(h, xs):
        layer_params, st, s_tm, s_cm = xs
        h2, (new_tm, new_st, new_cm) = block_apply(
            layer_params, h, cfg, shift_tm=s_tm, state=st, shift_cm=s_cm)
        return h2, (new_st, new_tm, new_cm)

    x, (new_state, new_tm, new_cm) = jax.lax.scan(
        body, x, (params["blocks"], cache["state"], cache["shift_tm"],
                  cache["shift_cm"]))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.lm_head(params.get("lm_head", {}), x, cfg,
                       embed_params=params["embed"])
    return logits, {"state": new_state, "shift_tm": new_tm,
                    "shift_cm": new_cm, "index": cache["index"] + 1}
