"""InternVL2-1b backbone: InternLM2-style LM consuming stubbed ViT patches.

Per the assignment, the modality frontend is a STUB: ``input_specs()``
provides precomputed patch embeddings (B, n_patches, frontend_dim); a linear
connector projects them into the LM embedding space and they are prepended to
the token embeddings (the InternVL "LLM-as-decoder" wiring).  Loss is over
text positions only.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel.sharding import constrain
from . import layers as L
from . import transformer as TF
from .param import LeafSpec

Params = Dict[str, Any]


def vlm_spec(cfg: ModelConfig) -> Params:
    spec = TF.transformer_spec(cfg)
    spec["connector"] = {
        "w": LeafSpec((cfg.frontend_dim, cfg.d_model), ("patches", "embed")),
        "b": LeafSpec((cfg.d_model,), ("embed",), init="zeros"),
    }
    return spec


def forward(params: Params, tokens: jax.Array, patches: jax.Array,
            cfg: ModelConfig) -> jax.Array:
    """tokens: (B, S_text); patches: (B, P, frontend_dim) ->
    logits over text positions (B, S_text, V)."""
    B, P, _ = patches.shape
    vis = patches.astype(L.cdtype(cfg)) @ params["connector"]["w"].astype(
        L.cdtype(cfg)) + params["connector"]["b"].astype(L.cdtype(cfg))
    vis = constrain(vis, ("batch", "seq", "embed"))
    txt = L.embed(params["embed"], tokens, cfg)
    x = jnp.concatenate([vis, txt], axis=1)
    x = TF._scan_blocks(params, x, cfg)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    x = x[:, P:]                      # text positions only
    return L.lm_head(params.get("lm_head", {}), x, cfg,
                     embed_params=params["embed"])


def loss_fn(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig):
    logits = forward(params, batch["tokens"], batch["patches"], cfg)
    loss = L.softmax_xent(logits, batch["labels"])
    return loss, {"loss": loss}


# ----------------------------------------------------------------- serving
init_cache = TF.init_cache
cache_logical_axes = TF.cache_logical_axes


def decode_step(params: Params, tokens: jax.Array,
                cache: Dict[str, jax.Array], cfg: ModelConfig):
    """Text-token decode (the image prefix was consumed during prefill)."""
    return TF.decode_step(params, tokens, cache, cfg)
