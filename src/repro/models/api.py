"""Unified model API: one entry point per architecture family.

``build_model(cfg)`` returns a :class:`ModelAPI` whose members close over the
config: parameter spec (single source of truth for init / abstract shapes /
sharding axes), loss function, decode step, cache constructors, and the
ShapeDtypeStruct input specs the dry-run lowers against.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from . import encdec, moe, param as P, rwkv6, transformer, vlm, zamba2

Params = Dict[str, Any]


@dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    spec: Params
    loss_fn: Callable[[Params, Dict[str, jax.Array]], Tuple[jax.Array, Dict]]
    logits_fn: Callable[[Params, Dict[str, jax.Array]], jax.Array]
    decode_step: Optional[Callable]
    init_cache: Optional[Callable]
    cache_axes: Optional[Callable]

    # -- params -------------------------------------------------------------
    def init(self, rng: jax.Array) -> Params:
        return P.materialize(rng, self.spec)

    def abstract_params(self) -> Params:
        return P.abstract(self.spec)

    def param_axes(self) -> Params:
        return P.axes_of(self.spec)

    def n_params(self) -> int:
        return P.count_params(self.spec)

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only routed experts count)."""
        cfg = self.cfg
        if not cfg.n_experts:
            return self.n_params()
        total = 0
        for path, leaf in _iter_leaves(self.spec):
            size = 1
            for s in leaf.shape:
                size *= s
            if "experts" in leaf.axes:
                frac = (cfg.experts_per_token or cfg.n_experts) / cfg.n_experts
                size = int(size * frac)
            total += size
        return total

    # -- input specs (ShapeDtypeStruct stand-ins; NO allocation) --------------
    def input_specs(self, shape: ShapeConfig,
                    batch_override: Optional[int] = None) -> Dict[str, Any]:
        cfg = self.cfg
        B = batch_override or shape.global_batch
        S = shape.seq_len
        i32 = jnp.int32
        if shape.kind == "train" or shape.kind == "prefill":
            specs: Dict[str, Any] = {
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32),
            }
            if cfg.family == "vlm":
                specs["patches"] = jax.ShapeDtypeStruct(
                    (B, cfg.frontend_len, cfg.frontend_dim), jnp.bfloat16)
            if cfg.family == "audio":
                specs["frames"] = jax.ShapeDtypeStruct(
                    (B, cfg.frontend_len, cfg.frontend_dim), jnp.bfloat16)
                specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
                specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
            return specs
        if shape.kind == "decode":
            assert self.init_cache is not None, f"{cfg.name} has no decode step"
            cache = jax.eval_shape(lambda: self.init_cache(cfg, B, S))
            return {
                "tokens": jax.ShapeDtypeStruct((B, 1), i32),
                "cache": cache,
            }
        raise ValueError(shape.kind)


def _iter_leaves(spec, prefix=()):
    if isinstance(spec, P.LeafSpec):
        yield prefix, spec
        return
    if isinstance(spec, dict):
        for k, v in spec.items():
            yield from _iter_leaves(v, prefix + (k,))


def _cast(spec, cfg: ModelConfig):
    return P.cast_spec_dtype(spec, jnp.dtype(cfg.param_dtype))


def build_model(cfg: ModelConfig) -> ModelAPI:
    fam = cfg.family
    if fam in ("dense",):
        return ModelAPI(
            cfg=cfg, spec=_cast(transformer.transformer_spec(cfg), cfg),
            loss_fn=lambda p, b: transformer.loss_fn(p, b, cfg),
            logits_fn=lambda p, b: transformer.forward(p, b["tokens"], cfg),
            decode_step=lambda p, t, c: transformer.decode_step(p, t, c, cfg),
            init_cache=transformer.init_cache,
            cache_axes=transformer.cache_logical_axes)
    if fam == "moe":
        return ModelAPI(
            cfg=cfg, spec=_cast(moe.moe_spec(cfg), cfg),
            loss_fn=lambda p, b: moe.loss_fn(p, b, cfg),
            logits_fn=lambda p, b: moe.forward(p, b["tokens"], cfg)[0],
            decode_step=lambda p, t, c: moe.decode_step(p, t, c, cfg),
            init_cache=moe.init_cache,
            cache_axes=moe.cache_logical_axes)
    if fam == "ssm":
        return ModelAPI(
            cfg=cfg, spec=_cast(rwkv6.rwkv6_spec(cfg), cfg),
            loss_fn=lambda p, b: rwkv6.loss_fn(p, b, cfg),
            logits_fn=lambda p, b: rwkv6.forward(p, b["tokens"], cfg),
            decode_step=lambda p, t, c: rwkv6.decode_step(p, t, c, cfg),
            init_cache=rwkv6.init_cache,
            cache_axes=rwkv6.cache_logical_axes)
    if fam == "hybrid":
        return ModelAPI(
            cfg=cfg, spec=_cast(zamba2.zamba2_spec(cfg), cfg),
            loss_fn=lambda p, b: zamba2.loss_fn(p, b, cfg),
            logits_fn=lambda p, b: zamba2.forward(p, b["tokens"], cfg),
            decode_step=lambda p, t, c: zamba2.decode_step(p, t, c, cfg),
            init_cache=zamba2.init_cache,
            cache_axes=zamba2.cache_logical_axes)
    if fam == "vlm":
        return ModelAPI(
            cfg=cfg, spec=_cast(vlm.vlm_spec(cfg), cfg),
            loss_fn=lambda p, b: vlm.loss_fn(p, b, cfg),
            logits_fn=lambda p, b: vlm.forward(p, b["tokens"], b["patches"],
                                               cfg),
            decode_step=lambda p, t, c: vlm.decode_step(p, t, c, cfg),
            init_cache=vlm.init_cache,
            cache_axes=vlm.cache_logical_axes)
    if fam == "audio":
        return ModelAPI(
            cfg=cfg, spec=_cast(encdec.encdec_spec(cfg), cfg),
            loss_fn=lambda p, b: encdec.loss_fn(p, b, cfg),
            logits_fn=lambda p, b: encdec.forward(p, b["frames"],
                                                  b["tokens"], cfg),
            decode_step=lambda p, t, c: encdec.decode_step(p, t, c, cfg),
            init_cache=encdec.init_cache,
            cache_axes=encdec.cache_logical_axes)
    raise ValueError(f"unknown family {fam!r}")
