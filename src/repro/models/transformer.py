"""Dense decoder-only transformer (gemma-7b, qwen2.5-3b, llama3-405b,
deepseek-67b; backbone of internvl2 and the MoE variants).

Pre-norm blocks, GQA + RoPE attention, SwiGLU/GeGLU MLP.  Layers are stacked
and executed with ``jax.lax.scan`` (+ optional remat) so HLO size and compile
time are depth-independent — essential for the 126-layer llama3-405b dry-run.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel.sharding import constrain
from . import layers as L
from .param import LeafSpec, stack_specs

Params = Dict[str, Any]


def block_spec(cfg: ModelConfig) -> Params:
    return {
        "attn_norm": L.rmsnorm_spec(cfg.d_model),
        "attn": L.attention_spec(cfg),
        "mlp_norm": L.rmsnorm_spec(cfg.d_model),
        "mlp": L.mlp_spec(cfg),
    }


def transformer_spec(cfg: ModelConfig) -> Params:
    spec: Params = {
        "embed": L.embedding_spec(cfg),
        "blocks": stack_specs(block_spec(cfg), cfg.n_layers),
        "final_norm": L.rmsnorm_spec(cfg.d_model),
    }
    spec.update({"lm_head": L.lm_head_spec(cfg)})
    return spec


def block_apply(p: Params, x: jax.Array, cfg: ModelConfig, *,
                kv_cache=None, cache_index=None, causal: bool = True):
    h = L.rmsnorm(p["attn_norm"], x, cfg.norm_eps)
    attn_out, new_cache = L.attention(p["attn"], h, cfg, causal=causal,
                                      kv_cache=kv_cache,
                                      cache_index=cache_index)
    x = x + attn_out
    h = L.rmsnorm(p["mlp_norm"], x, cfg.norm_eps)
    x = x + L.mlp(p["mlp"], h, cfg)
    return x, new_cache


def _scan_blocks(params: Params, x: jax.Array, cfg: ModelConfig,
                 causal: bool = True) -> jax.Array:
    def body(h, layer_params):
        h2, _ = block_apply(layer_params, h, cfg, causal=causal)
        return h2, None

    if cfg.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["blocks"])
    return x


def forward(params: Params, tokens: jax.Array, cfg: ModelConfig
            ) -> jax.Array:
    """tokens: (B, S) -> logits (B, S, V)."""
    x = L.embed(params["embed"], tokens, cfg)
    if cfg.name.startswith("gemma"):
        x = x * (cfg.d_model ** 0.5)        # gemma embedding scaling
    x = _scan_blocks(params, x, cfg)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return L.lm_head(params.get("lm_head", {}), x, cfg,
                     embed_params=params["embed"])


def loss_fn(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    tokens = batch["tokens"]
    B, S = tokens.shape
    if B * S * cfg.padded_vocab > L.FUSED_XENT_THRESHOLD:
        # fused chunked head+loss: never materializes (tokens x vocab) f32
        x = L.embed(params["embed"], tokens, cfg)
        if cfg.name.startswith("gemma"):
            x = x * (cfg.d_model ** 0.5)
        x = _scan_blocks(params, x, cfg)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        if cfg.tie_embeddings:
            loss = L.fused_head_xent(x, params["embed"]["table"],
                                     batch["labels"], w_is_vd=True)
        else:
            loss = L.fused_head_xent(x, params["lm_head"]["w"],
                                     batch["labels"])
        return loss, {"loss": loss}
    logits = forward(params, tokens, cfg)
    loss = L.softmax_xent(logits, batch["labels"])
    return loss, {"loss": loss}


# ----------------------------------------------------------------- serving
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim_)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "index": jnp.zeros((), jnp.int32),
    }


def cache_logical_axes() -> Dict[str, Tuple[Optional[str], ...]]:
    ax = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
    return {"k": ax, "v": ax, "index": ()}


def decode_step(params: Params, tokens: jax.Array,
                cache: Dict[str, jax.Array], cfg: ModelConfig
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode step.  tokens: (B, 1); cache k/v: (L, B, T, nkv, hd)."""
    x = L.embed(params["embed"], tokens, cfg)
    if cfg.name.startswith("gemma"):
        x = x * (cfg.d_model ** 0.5)
    idx = cache["index"]

    def body(h, xs):
        layer_params, ck, cv = xs
        h2, new_kv = block_apply(layer_params, h, cfg,
                                 kv_cache=(ck, cv), cache_index=idx)
        return h2, new_kv

    x, (new_k, new_v) = jax.lax.scan(
        body, x, (params["blocks"], cache["k"], cache["v"]))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.lm_head(params.get("lm_head", {}), x, cfg,
                       embed_params=params["embed"])
    new_cache = {"k": new_k, "v": new_v, "index": idx + tokens.shape[1]}
    return logits, new_cache


def prefill(params: Params, tokens: jax.Array, cache: Dict[str, jax.Array],
            cfg: ModelConfig) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Fill the cache with a full prompt (teacher-forced pass)."""
    return decode_step(params, tokens, cache, cfg)
