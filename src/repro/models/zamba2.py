"""Zamba2 hybrid: a Mamba2 backbone with a *shared* attention block applied
every ``attn_every`` SSM layers (zamba2-1.2b).

Weight sharing is the architecture's point: one attention block's parameters
are reused at every application site, so the scan is structured as

    outer scan over groups (n_layers / attn_every of them):
        inner scan over ``attn_every`` Mamba2 layers
        one application of the shared attention block

which keeps HLO depth-independent while giving each application its own KV
cache slot during decode ((G, B, T, nkv, hd)).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import layers as L
from .mamba2 import dims as mamba_dims, mamba2_apply, mamba2_spec
from .param import LeafSpec, stack_specs

Params = Dict[str, Any]


def _groups(cfg: ModelConfig) -> Tuple[int, int]:
    a = cfg.attn_every or cfg.n_layers
    assert cfg.n_layers % a == 0, (
        f"{cfg.name}: n_layers={cfg.n_layers} must be divisible by "
        f"attn_every={a}")
    return cfg.n_layers // a, a


def zamba2_spec(cfg: ModelConfig) -> Params:
    G, A = _groups(cfg)
    mamba_block = {
        "norm": L.rmsnorm_spec(cfg.d_model),
        "mamba": mamba2_spec(cfg),
    }
    return {
        "embed": L.embedding_spec(cfg),
        # stacked (G, A, ...) for the nested scan
        "blocks": stack_specs(stack_specs(mamba_block, A, "layers"), G,
                              "layers"),
        "shared_attn": {
            "norm": L.rmsnorm_spec(cfg.d_model),
            "attn": L.attention_spec(cfg),
            "mlp_norm": L.rmsnorm_spec(cfg.d_model),
            "mlp": L.mlp_spec(cfg),
        },
        "final_norm": L.rmsnorm_spec(cfg.d_model),
        "lm_head": L.lm_head_spec(cfg),
    }


def _shared_attn_apply(p: Params, x: jax.Array, cfg: ModelConfig, *,
                       kv_cache=None, cache_index=None):
    h = L.rmsnorm(p["norm"], x, cfg.norm_eps)
    o, new_cache = L.attention(p["attn"], h, cfg, causal=True,
                               kv_cache=kv_cache, cache_index=cache_index)
    x = x + o
    h = L.rmsnorm(p["mlp_norm"], x, cfg.norm_eps)
    return x + L.mlp(p["mlp"], h, cfg), new_cache


def forward(params: Params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = L.embed(params["embed"], tokens, cfg)
    shared = params["shared_attn"]

    def mamba_body(h, layer_params):
        hn = L.rmsnorm(layer_params["norm"], h, cfg.norm_eps)
        o, _ = mamba2_apply(layer_params["mamba"], hn, cfg)
        return h + o, None

    def group_body(h, group_params):
        h, _ = jax.lax.scan(mamba_body, h, group_params)
        h, _ = _shared_attn_apply(shared, h, cfg)
        return h, None

    if cfg.remat:
        group_body = jax.checkpoint(
            group_body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(group_body, x, params["blocks"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return L.lm_head(params.get("lm_head", {}), x, cfg,
                     embed_params=params["embed"])


def loss_fn(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig):
    logits = forward(params, batch["tokens"], cfg)
    loss = L.softmax_xent(logits, batch["labels"])
    return loss, {"loss": loss}


# ----------------------------------------------------------------- serving
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    G, A = _groups(cfg)
    d_inner, H, dh, ds = mamba_dims(cfg)
    conv_dim = d_inner + 2 * ds
    return {
        "ssd": jnp.zeros((G, A, batch, H, dh, ds), jnp.float32),
        "conv": jnp.zeros((G, A, batch, cfg.conv_kernel - 1, conv_dim), dtype),
        "attn_k": jnp.zeros((G, batch, max_len, cfg.n_kv_heads,
                             cfg.head_dim_), dtype),
        "attn_v": jnp.zeros((G, batch, max_len, cfg.n_kv_heads,
                             cfg.head_dim_), dtype),
        "index": jnp.zeros((), jnp.int32),
    }


def cache_logical_axes() -> Dict[str, Tuple[Optional[str], ...]]:
    return {
        "ssd": ("layers", None, "batch", "ssm_heads", None, None),
        "conv": ("layers", None, "batch", None, "ffn"),
        "attn_k": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
        "attn_v": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
        "index": (),
    }


def decode_step(params: Params, tokens: jax.Array,
                cache: Dict[str, jax.Array], cfg: ModelConfig):
    x = L.embed(params["embed"], tokens, cfg)
    shared = params["shared_attn"]
    idx = cache["index"]

    def mamba_body(h, xs):
        layer_params, ssd, conv = xs
        hn = L.rmsnorm(layer_params["norm"], h, cfg.norm_eps)
        o, (new_ssd, new_conv) = mamba2_apply(layer_params["mamba"], hn, cfg,
                                              ssd_state=ssd, conv_state=conv)
        return h + o, (new_ssd, new_conv)

    def group_body(h, xs):
        group_params, ssd, conv, ck, cv = xs
        h, (new_ssd, new_conv) = jax.lax.scan(mamba_body, h,
                                              (group_params, ssd, conv))
        h, new_kv = _shared_attn_apply(shared, h, cfg, kv_cache=(ck, cv),
                                       cache_index=idx)
        return h, (new_ssd, new_conv, new_kv[0], new_kv[1])

    x, (new_ssd, new_conv, new_k, new_v) = jax.lax.scan(
        group_body, x,
        (params["blocks"], cache["ssd"], cache["conv"],
         cache["attn_k"], cache["attn_v"]))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.lm_head(params.get("lm_head", {}), x, cfg,
                       embed_params=params["embed"])
    return logits, {"ssd": new_ssd, "conv": new_conv, "attn_k": new_k,
                    "attn_v": new_v, "index": idx + tokens.shape[1]}
