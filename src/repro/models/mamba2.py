"""Mamba2 (SSD) block — the state-space component of zamba2-1.2b.

Chunked SSD scan (jnp; same dataflow the TileLoom WKV kernel uses — the
recurrence admits only temporal reuse, DESIGN.md S5):

    h_t = exp(A dt_t) h_{t-1} + dt_t * (x_t (x) B_t)
    y_t = C_t . h_t + D * x_t

with per-head scalar decay A (n_groups = 1 simplification, documented).
Decode carries (ssd_state, conv_state) per layer — O(1) per token.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel.sharding import constrain
from .param import LeafSpec

Params = Dict[str, Any]
SSD_HEAD_DIM = 64


def dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = cfg.ssm_heads or d_inner // SSD_HEAD_DIM
    dh = d_inner // n_heads
    return d_inner, n_heads, dh, cfg.ssm_state


def mamba2_spec(cfg: ModelConfig) -> Params:
    d = cfg.d_model
    d_inner, H, dh, ds = dims(cfg)
    conv_dim = d_inner + 2 * ds
    return {
        "in_proj": LeafSpec((d, 2 * d_inner + 2 * ds + H), ("embed", "ffn")),
        "conv_w": LeafSpec((cfg.conv_kernel, conv_dim), ("conv", "ffn"),
                           init="scaled", scale=0.1),
        "conv_b": LeafSpec((conv_dim,), ("ffn",), init="zeros"),
        "A_log": LeafSpec((H,), ("ssm_heads",), init="scaled", scale=0.5),
        "D": LeafSpec((H,), ("ssm_heads",), init="ones"),
        "dt_bias": LeafSpec((H,), ("ssm_heads",), init="zeros"),
        "norm_scale": LeafSpec((d_inner,), ("ffn",), init="ones"),
        "out_proj": LeafSpec((d_inner, d), ("ffn", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv.  x: (B, T, C); w: (K, C).  Returns (y, new_state)
    where state is the last K-1 inputs (for decode)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)          # (B, T+K-1, C)
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :] for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else jnp.zeros(
        (x.shape[0], 0, x.shape[2]), x.dtype)
    return jax.nn.silu(y + b[None, None, :]), new_state


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bmat: jax.Array,
                Cmat: jax.Array, h0: Optional[jax.Array] = None,
                chunk: int = 32):
    """x: (B,T,H,dh); dt: (B,T,H); A: (H,) (negative); B/C: (B,T,ds).
    Returns (y, h_final) with h: (B,H,dh,ds)."""
    Bsz, T, H, dh = x.shape
    ds = Bmat.shape[-1]
    c = min(chunk, T)
    assert T % c == 0
    n = T // c
    da = (dt * A[None, None, :]).astype(jnp.float32)     # (B,T,H) <= 0
    xr = (x * dt[..., None]).astype(jnp.float32)         # dt-weighted input
    # chunked views, scanned over chunk index
    da_c = da.reshape(Bsz, n, c, H).transpose(1, 0, 2, 3)
    x_c = xr.reshape(Bsz, n, c, H, dh).transpose(1, 0, 2, 3, 4)
    B_c = Bmat.astype(jnp.float32).reshape(Bsz, n, c, ds).transpose(1, 0, 2, 3)
    C_c = Cmat.astype(jnp.float32).reshape(Bsz, n, c, ds).transpose(1, 0, 2, 3)
    t_i = jnp.arange(c)[:, None]
    s_i = jnp.arange(c)[None, :]
    mask = (t_i >= s_i).astype(jnp.float32)

    def step(h, xs):
        dac, xc, bc, cc = xs
        cum = jnp.cumsum(dac, axis=1)                    # (B,c,H) inclusive
        # intra-chunk: scores[t,s] = e^{cum[t]-cum[s]} (C_t . B_s), s <= t.
        # valid (t >= s) differences are <= 0; clamping before exp keeps the
        # masked upper triangle from overflowing to inf (inf*0 = nan)
        diff = jnp.minimum(cum[:, :, None, :] - cum[:, None, :, :], 0.0)
        seg = jnp.exp(diff)                              # (B,c,c,H)
        cb = jnp.einsum("btd,bsd->bts", cc, bc)
        scores = seg * cb[..., None] * mask[None, :, :, None]
        y = jnp.einsum("btsh,bshd->bthd", scores, xc)
        # inter-chunk: read of carried state with decay e^{cum[t]}
        y = y + jnp.einsum("btd,bhed,bth->bthe", cc, h, jnp.exp(cum))
        # state update
        decay_all = jnp.exp(cum[:, -1])                  # (B,H)
        k_carry = jnp.exp(cum[:, -1][:, None, :] - cum)  # (B,c,H)
        h = (h * decay_all[:, :, None, None]
             + jnp.einsum("bthd,bth,bts->bhds", xc, k_carry, bc))
        return h, y

    if h0 is None:
        h0 = jnp.zeros((Bsz, H, dh, ds), jnp.float32)
    h, y = jax.lax.scan(step, h0, (da_c, x_c, B_c, C_c))
    y = y.transpose(1, 0, 2, 3, 4).reshape(Bsz, T, H, dh)
    return y, h


def mamba2_apply(p: Params, x: jax.Array, cfg: ModelConfig, *,
                 ssd_state: Optional[jax.Array] = None,
                 conv_state: Optional[jax.Array] = None):
    """Returns (out, (new_ssd_state, new_conv_state)); states are None during
    training (chunked scan starts from zero)."""
    B, T, d = x.shape
    d_inner, H, dh, ds = dims(cfg)
    proj = x @ p["in_proj"].astype(x.dtype)
    z, xin, Bm, Cm, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + ds, 2 * d_inner + 2 * ds],
        axis=-1)
    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"].astype(x.dtype),
                                      p["conv_b"].astype(x.dtype), conv_state)
    xin, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + ds], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32)[None, None, :])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xin.reshape(B, T, H, dh)
    xh = constrain(xh, ("batch", "seq", "ssm_heads", None))
    if ssd_state is None:
        y, new_state = ssd_chunked(xh, dt, A, Bm, Cm)
    else:
        # single-token recurrence (decode)
        da = jnp.exp(dt[:, 0] * A[None, :])              # (B,H)
        xr = (xh[:, 0] * dt[:, 0][..., None]).astype(jnp.float32)
        upd = jnp.einsum("bhd,bs->bhds", xr, Bm[:, 0].astype(jnp.float32))
        new_state = ssd_state * da[:, :, None, None] + upd
        y = jnp.einsum("bs,bhds->bhd", Cm[:, 0].astype(jnp.float32),
                       new_state)[:, None]
    y = y.astype(x.dtype).reshape(B, T, d_inner) \
        + xin * jnp.repeat(p["D"].astype(x.dtype), dh)[None, None, :]
    # gated RMS norm
    y32 = (y * jax.nn.silu(z)).astype(jnp.float32)
    var = jnp.mean(jnp.square(y32), axis=-1, keepdims=True)
    y = (y32 * jax.lax.rsqrt(var + cfg.norm_eps)
         * p["norm_scale"].astype(jnp.float32)).astype(x.dtype)
    out = y @ p["out_proj"].astype(x.dtype)
    return constrain(out, ("batch", "seq", "embed")), (new_state, new_conv)
