"""Mixture-of-Experts transformer (qwen3-moe-30b-a3b, deepseek-moe-16b).

Sort-based capacity dispatch (O(T*k) memory — no T x E x cap one-hots, so the
32k-prefill dry-run fits):

1. router softmax -> top-k experts/weights per token;
2. flatten (token, slot) pairs, sort by expert id;
3. rank-in-expert via sorted-position minus group offset; drop beyond
   capacity;
4. scatter into the dense (E, cap, d) buffer, run the grouped expert FFN
   (``kernels.moe_gmm`` on the pallas path, einsum on the xla path),
   scatter-add back with the gate weights.

DeepSeekMoE details honoured: ``n_shared_experts`` dense experts always on
(fine-grained experts with small ``moe_d_ff``), plus the standard
load-balancing auxiliary loss.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel.sharding import constrain
from . import layers as L
from .param import LeafSpec, stack_specs

Params = Dict[str, Any]


def moe_mlp_spec(cfg: ModelConfig) -> Params:
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    spec: Params = {
        "router": LeafSpec((d, E), ("embed", "experts")),
        "w_gate": LeafSpec((E, d, f), ("experts", "embed", "ffn")),
        "w_up": LeafSpec((E, d, f), ("experts", "embed", "ffn")),
        "w_down": LeafSpec((E, f, d), ("experts", "ffn", "embed")),
    }
    if cfg.n_shared_experts:
        spec["shared"] = L.mlp_spec(cfg, d_ff=cfg.moe_d_ff * cfg.n_shared_experts)
    return spec


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    cap = int(math.ceil(tokens * cfg.experts_per_token * cfg.capacity_factor
                        / cfg.n_experts))
    return max(8, -(-cap // 8) * 8)      # round up to a multiple of 8


def _dispatch_ffn_combine(xf: jax.Array, p_gate, p_up, p_down,
                          gate_vals: jax.Array, expert_idx: jax.Array,
                          cfg: ModelConfig, e_lo, n_local: int,
                          cap: int) -> jax.Array:
    """Sort-based dispatch -> grouped FFN -> weighted combine, for the expert
    slice ``[e_lo, e_lo + n_local)`` over local tokens ``xf`` (T, d).

    Runs unchanged in two regimes: whole-mesh (e_lo=0, n_local=E) and inside
    the shard_map expert-parallel path (each model-rank owns E/TP experts and
    produces a partial sum over its slice).
    """
    T, d = xf.shape
    k = expert_idx.shape[-1]
    e_flat = expert_idx.reshape(T * k)
    w_flat = gate_vals.reshape(T * k)
    tok_flat = jnp.arange(T * k, dtype=jnp.int32) // k
    local = e_flat - e_lo                                     # local slot id
    in_range = (local >= 0) & (local < n_local)
    local_c = jnp.where(in_range, local, n_local)             # park OOR at end
    order = jnp.argsort(local_c)                              # stable
    se = local_c[order]
    st = tok_flat[order]
    sw = w_flat[order]
    counts = jnp.bincount(local_c, length=n_local + 1)[:n_local]
    starts = jnp.cumsum(counts) - counts                      # (n_local,)
    se_c = jnp.minimum(se, n_local - 1)
    rank = jnp.arange(T * k, dtype=jnp.int32) - starts[se_c]
    keep = (se < n_local) & (rank >= 0) & (rank < cap)
    rank_c = jnp.where(keep, rank, 0)

    xe = jnp.zeros((n_local, cap, d), xf.dtype)
    xe = xe.at[se_c, rank_c].add(
        jnp.where(keep[:, None], xf[st], 0).astype(xf.dtype))

    act = jax.nn.gelu if cfg.mlp_activation == "gelu" else jax.nn.silu
    if cfg.kernels == "pallas":
        from repro.kernels import ops
        g = ops.grouped_matmul(xe, p_gate.astype(xf.dtype))
        u = ops.grouped_matmul(xe, p_up.astype(xf.dtype))
        h = act(g) * u
        ye = ops.grouped_matmul(h, p_down.astype(xf.dtype))
    else:
        g = jnp.einsum("ecd,edf->ecf", xe, p_gate.astype(xf.dtype))
        u = jnp.einsum("ecd,edf->ecf", xe, p_up.astype(xf.dtype))
        h = act(g) * u
        ye = jnp.einsum("ecf,efd->ecd", h, p_down.astype(xf.dtype))

    gathered = ye[se_c, rank_c] * jnp.where(keep, sw, 0.0)[:, None
                                                           ].astype(xf.dtype)
    return jnp.zeros((T, d), xf.dtype).at[st].add(gathered)


def _router(xf: jax.Array, router_w: jax.Array, cfg: ModelConfig):
    logits = jnp.einsum("td,de->te", xf, router_w.astype(xf.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, cfg.experts_per_token)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    # load-balancing auxiliary loss (Switch-style)
    E = cfg.n_experts
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(expert_idx, E, dtype=jnp.float32),
                          axis=1), axis=0)
    aux = E * jnp.sum(me * ce) * cfg.router_aux_weight
    return gate_vals, expert_idx, aux


def _ep_axes() -> Tuple[Optional[Tuple[str, ...]], Optional[str]]:
    """(token mesh axes, expert mesh axis) from the active plan, if the mesh
    context makes the shard_map expert-parallel path applicable."""
    from repro.parallel import sharding as SH
    plan, mesh = SH._CTX.plan, SH._CTX.mesh
    if plan is None or mesh is None:
        return None, None
    e_ax = plan.mesh_axes("experts")
    if not isinstance(e_ax, str) or e_ax not in mesh.shape:
        return None, None
    b_ax = plan.mesh_axes("batch")
    if b_ax is None:
        b_axes: Tuple[str, ...] = ()
    else:
        b_axes = (b_ax,) if isinstance(b_ax, str) else tuple(
            a for a in b_ax if a in mesh.shape)
    return b_axes, e_ax


def moe_mlp(p: Params, x: jax.Array, cfg: ModelConfig
            ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss).

    Two execution paths:
    * **shard_map expert-parallel** (active when the current ShardingPlan maps
      'experts' to a mesh axis): tokens stay local to their data shard,
      each model-rank runs only its E/TP expert slice and the partial outputs
      are psum'd over the expert axis — no data-dependent scatter ever
      crosses a shard boundary (GSPMD cannot shard those; see DESIGN.md S8).
    * **single-shard** fallback (tests, CPU smoke): same dispatch over all E.
    """
    try:
        from jax import shard_map
    except ImportError:              # jax < 0.6: experimental namespace
        from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.parallel import sharding as SH

    B, S, d = x.shape
    b_axes, e_ax = _ep_axes()
    mesh = SH._CTX.mesh
    if e_ax is not None and cfg.n_experts % mesh.shape[e_ax] == 0 \
            and B % max(1, math.prod(mesh.shape[a] for a in b_axes)) == 0:
        ep = mesh.shape[e_ax]
        n_local = cfg.n_experts // ep
        bspec = tuple(b_axes) if len(b_axes) > 1 else (
            b_axes[0] if b_axes else None)

        def local_moe(xl, router_w, wg, wu, wd):
            Bl, Sl, _ = xl.shape
            xf = xl.reshape(Bl * Sl, d)
            gate_vals, expert_idx, aux = _router(xf, router_w, cfg)
            e_lo = jax.lax.axis_index(e_ax) * n_local
            cap = _capacity(Bl * Sl, cfg)
            yf = _dispatch_ffn_combine(xf, wg, wu, wd, gate_vals,
                                       expert_idx, cfg, e_lo, n_local, cap)
            yf = jax.lax.psum(yf, e_ax)
            aux = jax.lax.pmean(aux, e_ax)
            if b_axes:
                aux = jax.lax.pmean(aux, b_axes)
            return yf.reshape(Bl, Sl, d), aux

        y, aux = shard_map(
            local_moe, mesh=mesh,
            in_specs=(P(bspec, None, None), P(None, None),
                      P(e_ax, None, None), P(e_ax, None, None),
                      P(e_ax, None, None)),
            out_specs=(P(bspec, None, None), P()),
        )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    else:
        xf = x.reshape(B * S, d)
        gate_vals, expert_idx, aux = _router(xf, p["router"], cfg)
        cap = _capacity(B * S, cfg)
        yf = _dispatch_ffn_combine(xf, p["w_gate"], p["w_up"], p["w_down"],
                                   gate_vals, expert_idx, cfg, 0,
                                   cfg.n_experts, cap)
        y = yf.reshape(B, S, d)

    if "shared" in p:
        y = y + L.mlp(p["shared"], x, cfg)
    return constrain(y, ("batch", "seq", "embed")), aux


# ------------------------------------------------------------------- model
def moe_block_spec(cfg: ModelConfig) -> Params:
    return {
        "attn_norm": L.rmsnorm_spec(cfg.d_model),
        "attn": L.attention_spec(cfg),
        "mlp_norm": L.rmsnorm_spec(cfg.d_model),
        "moe": moe_mlp_spec(cfg),
    }


def moe_spec(cfg: ModelConfig) -> Params:
    spec: Params = {
        "embed": L.embedding_spec(cfg),
        "blocks": stack_specs(moe_block_spec(cfg), cfg.n_layers),
        "final_norm": L.rmsnorm_spec(cfg.d_model),
        "lm_head": L.lm_head_spec(cfg),
    }
    return spec


def _moe_block_apply(p: Params, x: jax.Array, cfg: ModelConfig, *,
                     kv_cache=None, cache_index=None):
    h = L.rmsnorm(p["attn_norm"], x, cfg.norm_eps)
    attn_out, new_cache = L.attention(p["attn"], h, cfg, causal=True,
                                      kv_cache=kv_cache,
                                      cache_index=cache_index)
    x = x + attn_out
    h = L.rmsnorm(p["mlp_norm"], x, cfg.norm_eps)
    y, aux = moe_mlp(p["moe"], h, cfg)
    return x + y, aux, new_cache


def forward(params: Params, tokens: jax.Array, cfg: ModelConfig
            ) -> Tuple[jax.Array, jax.Array]:
    """-> (logits, total_aux_loss)."""
    x = L.embed(params["embed"], tokens, cfg)

    def body(carry, layer_params):
        h, aux = carry
        h2, a, _ = _moe_block_apply(layer_params, h, cfg)
        return (h2, aux + a), None

    body_fn = body
    if cfg.remat:
        body_fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.lm_head(params.get("lm_head", {}), x, cfg,
                       embed_params=params["embed"])
    return logits, aux


def loss_fn(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux = forward(params, batch["tokens"], cfg)
    xent = L.softmax_xent(logits, batch["labels"])
    return xent + aux, {"loss": xent, "aux_loss": aux}


# ----------------------------------------------------------------- serving
from .transformer import cache_logical_axes, init_cache  # same cache layout


def decode_step(params: Params, tokens: jax.Array,
                cache: Dict[str, jax.Array], cfg: ModelConfig
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    x = L.embed(params["embed"], tokens, cfg)
    idx = cache["index"]

    def body(h, xs):
        layer_params, ck, cv = xs
        h2, _, new_kv = _moe_block_apply(layer_params, h, cfg,
                                         kv_cache=(ck, cv), cache_index=idx)
        return h2, new_kv

    x, (new_k, new_v) = jax.lax.scan(
        body, x, (params["blocks"], cache["k"], cache["v"]))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.lm_head(params.get("lm_head", {}), x, cfg,
                       embed_params=params["embed"])
    return logits, {"k": new_k, "v": new_v, "index": idx + tokens.shape[1]}
