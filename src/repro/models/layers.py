"""Shared model building blocks (pure-functional JAX).

Every block takes ``(params, x, cfg, ...)`` and is sharding-annotated with
logical axes via ``parallel.sharding.constrain``.  Attention and the MLP have
two kernel paths: ``"xla"`` (plain jnp; fused by XLA — used by smoke tests and
the dry-run whose roofline reads XLA HLO) and ``"pallas"`` (the TPU kernels of
``repro.kernels``, interpret-validated on CPU).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel.sharding import constrain
from .param import LeafSpec

Params = Dict[str, Any]


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ------------------------------------------------------------------ norms
def rmsnorm_spec(d: int) -> Params:
    return {"scale": LeafSpec((d,), ("embed",), init="ones")}


def rmsnorm(p: Params, x: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(dt)


# ------------------------------------------------------------------- RoPE
def rope_frequencies(head_dim: int, theta: float, positions: jax.Array
                     ) -> Tuple[jax.Array, jax.Array]:
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[..., None] * freq   # (..., S, half)
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, D) with D even; cos/sin: (S, D/2) or broadcastable."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[None, :, None, :]
    s = sin[None, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(x.dtype)


# -------------------------------------------------------------- attention
def attention_spec(cfg: ModelConfig, cross: bool = False) -> Params:
    d, hd = cfg.d_model, cfg.head_dim_
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    spec: Params = {
        "wq": LeafSpec((d, nh, hd), ("embed", "q_heads", "head_dim")),
        "wk": LeafSpec((d, nkv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": LeafSpec((d, nkv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": LeafSpec((nh, hd, d), ("q_heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        spec["bq"] = LeafSpec((nh, hd), ("q_heads", "head_dim"), init="zeros")
        spec["bk"] = LeafSpec((nkv, hd), ("kv_heads", "head_dim"), init="zeros")
        spec["bv"] = LeafSpec((nkv, hd), ("kv_heads", "head_dim"), init="zeros")
    return spec


def _project_qkv(p: Params, x: jax.Array, cfg: ModelConfig,
                 kv_input: Optional[jax.Array] = None):
    kv_x = x if kv_input is None else kv_input
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", kv_x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", kv_x, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = constrain(q, ("batch", "seq", "q_heads", "head_dim"))
    k = constrain(k, ("batch", "kv_seq", "kv_heads", "head_dim"))
    v = constrain(v, ("batch", "kv_seq", "kv_heads", "head_dim"))
    return q, k, v


def _repeat_kv(k: jax.Array, q_per_kv: int) -> jax.Array:
    if q_per_kv == 1:
        return k
    return jnp.repeat(k, q_per_kv, axis=2)


CHUNKED_ATTN_THRESHOLD = 8192     # dense S x T scores above this use chunking


def _sdpa_xla_chunked(q, k, v, causal: bool, sm_scale: float,
                      q_block: int = 1024, kv_block: int = 1024) -> jax.Array:
    """Flash-style online-softmax attention in plain jnp: lax.scan over query
    blocks, inner scan over KV blocks — O(q_block x kv_block) score memory
    instead of O(S x T).  This is the XLA-path analogue of the Pallas flash
    kernel, required for the 32k prefill cells (a dense 32k x 32k x heads f32
    score tensor is ~120 GB/device; measured in the dry-run)."""
    B, S, H, D = q.shape
    T = k.shape[1]

    def _fit(n, desired):                 # largest pow2 divisor <= desired
        b = 1
        while b * 2 <= desired and n % (b * 2) == 0:
            b *= 2
        return b

    kb = _fit(T, min(kv_block, T))
    if T % kb or kb < 8:
        return _sdpa_xla_dense(q, k, v, causal, sm_scale, None)
    nk = T // kb
    # q is NOT re-blocked: reshaping a sharded seq dim would break GSPMD
    # propagation (measured: tp2d prefill went from 289 GB to fitting once
    # kv-only blocking landed).  Score memory per step: (B, S, kb, H).
    qf = q.astype(jnp.float32)
    m0 = jnp.full((B, S, H), -1e30, jnp.float32)
    l0 = jnp.zeros((B, S, H), jnp.float32)
    a0 = jnp.zeros((B, S, H, D), jnp.float32)
    qpos = jnp.arange(S)[:, None] + (T - S)

    def kv_step(carry, kj):
        m, l, acc = carry
        kblk = jax.lax.dynamic_slice_in_dim(k, kj * kb, kb, axis=1)
        vblk = jax.lax.dynamic_slice_in_dim(v, kj * kb, kb, axis=1)
        s = jnp.einsum("bqhd,bthd->bqth", qf, kblk.astype(jnp.float32))
        s = s * sm_scale
        if causal:
            kpos = kj * kb + jnp.arange(kb)[None, :]
            s = jnp.where((qpos >= kpos)[None, :, :, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=2))
        p = jnp.exp(s - m_new[:, :, None, :])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=2)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bqth,bthd->bqhd", p, vblk.astype(jnp.float32))
        return (m_new, l, acc), None

    (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
    l = jnp.where(l == 0.0, 1.0, l)
    return (acc / l[..., None]).astype(q.dtype)


def _sdpa_xla(q, k, v, causal: bool, sm_scale: float,
              kv_valid_len: Optional[jax.Array] = None) -> jax.Array:
    """Dispatch: dense scores for short sequences, flash-style chunking for
    long ones (decode S=1 always dense — its score row is (B,H,1,T))."""
    S, T = q.shape[1], k.shape[1]
    if S > 1 and kv_valid_len is None and S * T > CHUNKED_ATTN_THRESHOLD ** 2:
        # adaptive kv block: keep the global per-step score tensor
        # (B x S x kb x H x 4B) under ~64 GB so its shard stays transient-small
        B, H = q.shape[0], q.shape[2]
        row = B * S * H * 4
        kb = 1024
        while kb > 8 and row * kb > 64e9:
            kb //= 2
        return _sdpa_xla_chunked(q, k, v, causal, sm_scale, kv_block=kb)
    return _sdpa_xla_dense(q, k, v, causal, sm_scale, kv_valid_len)


def _sdpa_xla_dense(q, k, v, causal: bool, sm_scale: float,
                    kv_valid_len: Optional[jax.Array] = None) -> jax.Array:
    """q: (B,S,H,D), k/v: (B,T,H,D) -> (B,S,H,D)."""
    s = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    if causal:
        S, T = s.shape[-2], s.shape[-1]
        qi = jnp.arange(S)[:, None] + (T - S)   # align ends (decode-friendly)
        ki = jnp.arange(T)[None, :]
        s = jnp.where(qi >= ki, s, -jnp.inf)
    if kv_valid_len is not None:
        T = s.shape[-1]
        ki = jnp.arange(T)
        s = jnp.where((ki < kv_valid_len)[None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def _sdpa_pallas(q, k, v, causal: bool, sm_scale: float) -> jax.Array:
    from repro.kernels import ops
    B, S, H, D = q.shape
    T = k.shape[1]
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, T, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, T, D)
    if S == 1:
        out = ops.flash_decode(qf, kf, vf, sm_scale=sm_scale)
    else:
        out = ops.attention(qf, kf, vf, sm_scale=sm_scale, causal=causal)
    return out.reshape(B, H, S, D).transpose(0, 2, 1, 3)


def attention(p: Params, x: jax.Array, cfg: ModelConfig, *,
              causal: bool = True,
              positions: Optional[jax.Array] = None,
              kv_cache: Optional[Tuple[jax.Array, jax.Array]] = None,
              cache_index: Optional[jax.Array] = None,
              kv_input: Optional[jax.Array] = None,
              precomputed_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
              use_rope: bool = True):
    """GQA attention.  Returns (out, new_kv_cache | None).

    * train/prefill: ``kv_cache is None`` — full self (or cross) attention.
    * decode: ``kv_cache=(k, v)`` of shape (B, T, nkv, hd); the current
      token's k/v are inserted at ``cache_index``.
    * cross-attention: ``kv_input`` projects k/v from another sequence, or
      ``precomputed_kv`` supplies already-projected (k, v) (cached cross
      attention during decode).
    """
    B, S, d = x.shape
    hd = cfg.head_dim_
    if precomputed_kv is not None:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
        if "bq" in p:
            q = q + p["bq"].astype(x.dtype)
        k, v = precomputed_kv
        kr = _repeat_kv(k.astype(x.dtype), cfg.q_per_kv)
        vr = _repeat_kv(v.astype(x.dtype), cfg.q_per_kv)
        out = _sdpa_xla(q, kr, vr, False, hd ** -0.5)
        out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
        return constrain(out, ("batch", "seq", "embed")), None
    q, k, v = _project_qkv(p, x, cfg, kv_input)
    if use_rope and kv_input is None:
        pos = positions if positions is not None else jnp.arange(S)
        cos, sin = rope_frequencies(hd, cfg.rope_theta, pos)
        if kv_cache is not None and cache_index is not None:
            qpos = cache_index + jnp.arange(S)
            qcos, qsin = rope_frequencies(hd, cfg.rope_theta, qpos)
            q = apply_rope(q, qcos, qsin)
            k = apply_rope(k, qcos, qsin)
        else:
            q = apply_rope(q, cos, sin)
            k = apply_rope(k, cos, sin)
    new_cache = None
    if kv_cache is not None:
        ck, cv = kv_cache
        if cache_index is not None:
            ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype),
                                                     cache_index, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype),
                                                     cache_index, axis=1)
        k, v = ck, cv
        new_cache = (ck, cv)
        k = constrain(k, ("batch", "kv_seq", "kv_heads", "head_dim"))
        v = constrain(v, ("batch", "kv_seq", "kv_heads", "head_dim"))
    kr = _repeat_kv(k, cfg.q_per_kv)
    vr = _repeat_kv(v, cfg.q_per_kv)
    sm_scale = hd ** -0.5
    is_causal = causal and kv_input is None and kv_cache is None
    if cfg.kernels == "pallas" and (kv_cache is None or cache_index is None):
        # pallas decode path assumes a fully-valid cache (production kernels
        # take a length scalar; the xla path below masks exactly)
        out = _sdpa_pallas(q, kr, vr, is_causal, sm_scale)
    else:
        valid = (cache_index + S) if (kv_cache is not None
                                      and cache_index is not None) else None
        out = _sdpa_xla(q, kr, vr, is_causal, sm_scale, kv_valid_len=valid)
    out = constrain(out, ("batch", "seq", "q_heads", "head_dim"))
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    out = constrain(out, ("batch", "seq", "embed"))
    return out, new_cache


# -------------------------------------------------------------------- MLP
def mlp_spec(cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "w_gate": LeafSpec((d, f), ("embed", "ffn")),
        "w_up": LeafSpec((d, f), ("embed", "ffn")),
        "w_down": LeafSpec((f, d), ("ffn", "embed")),
    }


def mlp(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    act = jax.nn.gelu if cfg.mlp_activation == "gelu" else jax.nn.silu
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
    h = act(g) * u
    h = constrain(h, ("batch", "seq", "ffn"))
    out = jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))
    return constrain(out, ("batch", "seq", "embed"))


# -------------------------------------------------------------- embeddings
def embedding_spec(cfg: ModelConfig) -> Params:
    return {"table": LeafSpec((cfg.padded_vocab, cfg.d_model),
                              ("vocab", "embed"), scale=1.0)}


def embed(p: Params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = jnp.take(p["table"].astype(cdtype(cfg)), tokens, axis=0)
    return constrain(x, ("batch", "seq", "embed"))


def lm_head_spec(cfg: ModelConfig) -> Params:
    if cfg.tie_embeddings:
        return {}
    return {"w": LeafSpec((cfg.d_model, cfg.padded_vocab), ("embed", "vocab"))}


def lm_head(p: Params, x: jax.Array, cfg: ModelConfig,
            embed_params: Optional[Params] = None) -> jax.Array:
    if cfg.tie_embeddings:
        w = embed_params["table"].astype(x.dtype).T
    else:
        w = p["w"].astype(x.dtype)
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    return constrain(logits, ("batch", "seq", "vocab"))


# ------------------------------------------------------------------ losses
def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token cross-entropy, numerically stable in f32."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# tokens x vocab above this fuses head+loss.  Disabled by default: measured
# WORSE on XLA:CPU HLO-bytes (EXPERIMENTS.md SPerf B4 — the scan's carried
# state and bwd rematerialization outweigh the saved logits materialization
# when the logits are already vocab-sharded).  Opt in by lowering this.
FUSED_XENT_THRESHOLD = 1 << 60


def fused_head_xent(x: jax.Array, w: jax.Array, labels: jax.Array, *,
                    chunk: int = 2048, w_is_vd: bool = False) -> jax.Array:
    """LM head + cross-entropy fused over token chunks: the full
    (tokens x vocab) f32 logits tensor is never materialized — each chunk's
    logits live only inside one scan step (EXPERIMENTS.md §Perf B3).

    x: (B, S, d); w: (d, V); labels: (B, S) -> scalar mean xent.

    Chunks along the SEQUENCE axis only — reshaping (B, S) away would break
    GSPMD batch-sharding propagation (measured: 3.6x bytes regression; same
    lesson as the chunked attention, see _sdpa_xla_chunked).
    """
    B, S, d = x.shape
    eq = "bsd,vd->bsv" if w_is_vd else "bsd,dv->bsv"
    c = min(chunk, S)
    if S % c:
        logits = jnp.einsum(eq, x, w.astype(x.dtype))
        return softmax_xent(logits, labels)
    n = S // c

    def step(acc, i):
        xs = jax.lax.dynamic_slice_in_dim(x, i * c, c, axis=1)
        ls = jax.lax.dynamic_slice_in_dim(labels, i * c, c, axis=1)
        xs = constrain(xs, ("batch", "seq", "embed"))
        logits = jnp.einsum(eq, xs, w.astype(xs.dtype)).astype(jnp.float32)
        logits = constrain(logits, ("batch", "seq", "vocab"))
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ls[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(step, jnp.zeros((), jnp.float32), jnp.arange(n))
    return total / (B * S)
