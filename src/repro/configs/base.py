"""Model/runtime configuration dataclasses shared by the whole framework."""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | ssm | hybrid | moe | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    # -- dense options ---------------------------------------------------
    mlp_activation: str = "silu"     # silu (SwiGLU) | gelu (GeGLU)
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # -- MoE ---------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # -- SSM / hybrid --------------------------------------------------------
    ssm_state: int = 0
    ssm_heads: int = 0               # mamba2 heads (0 -> derived)
    ssm_expand: int = 2
    conv_kernel: int = 4
    attn_every: int = 0              # hybrid: shared attn every k blocks
    # -- encoder-decoder -------------------------------------------------------
    n_encoder_layers: int = 0
    is_encoder_decoder: bool = False
    # -- modality frontend stubs -------------------------------------------------
    frontend: str = "none"           # none | vision_stub | audio_stub
    frontend_dim: int = 0            # patch/frame embedding width
    frontend_len: int = 0            # patches/frames per sample
    # -- numerics / runtime -------------------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    kernels: str = "xla"             # xla | pallas
    remat: bool = True
    # sub-quadratic attention available (long_500k eligibility)
    subquadratic: bool = False

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so TP can shard the embedding
        and LM head (standard practice; logits over pad ids are never used
        as labels).  151655 -> 151680, 256206 -> 256256; others unchanged."""
        return -(-self.vocab_size // 256) * 256

    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(1, self.n_kv_heads)

    def reduced(self, **overrides) -> "ModelConfig":
        """A small same-family config for CPU smoke tests."""
        small = dict(
            n_layers=min(self.n_layers, 2 if self.attn_every == 0
                         else 2 * self.attn_every),
            d_model=128,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads,
                                  4 * self.n_kv_heads // max(1, self.n_heads)
                                  or 1)),
            d_ff=256,
            vocab_size=512,
            head_dim=32 if self.head_dim else 0,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            moe_d_ff=64 if self.n_experts else 0,
            experts_per_token=min(self.experts_per_token, 2)
            if self.experts_per_token else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            n_encoder_layers=min(self.n_encoder_layers, 2),
            frontend_dim=min(self.frontend_dim, 64) if self.frontend_dim else 0,
            frontend_len=min(self.frontend_len, 16) if self.frontend_len else 0,
            name=self.name + "-reduced",
        )
        small.update(overrides)
        return replace(self, **small)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    optimizer: str = "adamw"         # adamw | adafactor
    opt_state_dtype: str = "float32"  # bfloat16 for very large models
    microbatches: int = 1
    grad_compression: str = "none"   # none | int8
    seed: int = 0
