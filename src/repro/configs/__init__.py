# One config per assigned architecture (+ the shared shape cells).
from .base import ModelConfig, ShapeConfig, TrainConfig
from .registry import ARCHS, cells, cell_skip_reason, get_config, get_shape
from .shapes import SHAPES

__all__ = ["ModelConfig", "ShapeConfig", "TrainConfig", "ARCHS", "SHAPES",
           "cells", "cell_skip_reason", "get_config", "get_shape"]
