"""llama3-405b [dense]: 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256 [arXiv:2407.21783].  bf16 params (800 GB): the dry-run shards
them TP x ZeRO over the pod; optimizer state dtype bf16 (TrainConfig)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b", family="dense",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8,
    d_ff=53248, vocab_size=128256, head_dim=128,
    rope_theta=500_000.0, param_dtype="bfloat16",
)
