"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (MHA kv=16) 64 routed experts
top-6 + 2 shared, fine-grained d_ff=1408 [arXiv:2401.06066]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=102400,
    n_experts=64, experts_per_token=6, n_shared_experts=2, moe_d_ff=1408,
)
