"""Architecture/shape registry: ``--arch <id>`` resolution and the 40-cell
(arch x shape) enumeration used by the dry-run and roofline reports."""
from __future__ import annotations

from typing import Dict, Iterator, Optional, Tuple

from .base import ModelConfig, ShapeConfig
from .shapes import SHAPES
from . import (deepseek_67b, deepseek_moe_16b, gemma_7b, internvl2_1b,
               llama3_405b, qwen2_5_3b, qwen3_moe_30b_a3b, rwkv6_3b,
               seamless_m4t_medium, zamba2_1p2b)

ARCHS: Dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (gemma_7b, qwen2_5_3b, llama3_405b, deepseek_67b, rwkv6_3b,
              zamba2_1p2b, internvl2_1b, qwen3_moe_30b_a3b, deepseek_moe_16b,
              seamless_m4t_medium)
}


def get_config(name: str) -> ModelConfig:
    try:
        return ARCHS[name]
    except KeyError as e:
        raise KeyError(f"unknown arch {name!r}; available: "
                       f"{sorted(ARCHS)}") from e


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def cell_skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    """None if the cell runs; otherwise the documented skip reason."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return ("long_500k needs sub-quadratic attention; "
                f"{cfg.name} is pure full-attention (DESIGN.md S5)")
    return None


def cells(include_skipped: bool = False
          ) -> Iterator[Tuple[ModelConfig, ShapeConfig, Optional[str]]]:
    """All 40 (arch x shape) cells, with skip annotations."""
    for cfg in ARCHS.values():
        for shape in SHAPES.values():
            reason = cell_skip_reason(cfg, shape)
            if reason is None or include_skipped:
                yield cfg, shape, reason
