"""seamless-m4t-medium [audio]: enc-dec, 12L enc + 12L dec, d_model=1024 16H
d_ff=4096 vocab=256206 [arXiv:2308.11596].  The speech frontend is a STUB:
input_specs provides 1024 precomputed frame embeddings of width 160."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=256206,
    n_encoder_layers=12, is_encoder_decoder=True,
    frontend="audio_stub", frontend_dim=160, frontend_len=1024,
)
