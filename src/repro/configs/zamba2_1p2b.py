"""zamba2-1.2b [hybrid]: 38 Mamba2 blocks (ssm_state=64) + a shared attention
block (32H, d_ff=8192) applied every 2 SSM layers [arXiv:2411.15242].
attn_every=2 chosen so 38 % attn_every == 0 (DESIGN.md S5)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32000, head_dim=64,
    ssm_state=64, ssm_expand=2, conv_kernel=4, attn_every=2,
    subquadratic=True,
)
