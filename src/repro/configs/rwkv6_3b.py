"""rwkv6-3b "Finch" [ssm]: 32L d_model=2560 (attn-free, 40 heads of 64)
d_ff=8960 vocab=65536, data-dependent decay [arXiv:2404.05892]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=8960, vocab_size=65536, head_dim=64,
    subquadratic=True,
)
