"""internvl2-1b [vlm]: InternLM2-ish 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655 [arXiv:2404.16821].  InternViT frontend is a STUB: input_specs
provides 256 precomputed patch embeddings of width 1024."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4864, vocab_size=151655,
    rope_theta=1_000_000.0, tie_embeddings=True,
    frontend="vision_stub", frontend_dim=1024, frontend_len=256,
)
