"""Quickstart: plan a GEMM with TileLoom on the paper's Wormhole target, and
watch the two-step selection at work.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (SearchBudget, block_shape_candidates, estimate,
                        get_hw, matmul_program, plan_kernel_multi, simulate,
                        templates)

hw = get_hw("wormhole_8x8")
print("=== hardware (df dialect, paper S2.4) ===")
print(hw.df_text())

M = N = K = 2048
# front-end block-shape exploration (paper S2.1) + dataflow planning
progs = [matmul_program(M, N, K, bm=bm, bn=bn, bk=bk)
         for bm, bn, bk in block_shape_candidates(M, N, K)]
res = plan_kernel_multi(progs, hw, budget=SearchBudget(top_k=5))
print("\n=== TileLoom two-step selection ===")
print(res.summary())
print("\n=== chosen dataflow (paper Listing 5 style) ===")
print(res.best.plan.mlir_like(hw))

print("\n=== vs vendor templates ===")
for name, mk in (("TT-1D", templates.tt1d_matmul_plan),
                 ("TT-2D", templates.tt2d_matmul_plan),
                 ("TTNN", templates.ttnn_matmul_plan)):
    t = simulate(mk(M, N, K, hw), hw)
    print(f"{name:6s}: {t.total_s * 1e6:8.1f} us  ({t.tflops:5.1f} TFLOP/s)")
best = res.best.sim
print(f"TL    : {best.total_s * 1e6:8.1f} us  ({best.tflops:5.1f} TFLOP/s)")
