"""Quickstart: plan a GEMM with TileLoom on the paper's Wormhole target, and
watch the two-step selection at work.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (SearchBudget, block_shape_candidates, estimate,
                        get_hw, matmul_program, plan_kernel_multi, simulate,
                        templates)

hw = get_hw("wormhole_8x8")
print("=== hardware (df dialect, paper S2.4) ===")
print(hw.df_text())

M = N = K = 2048
# front-end block-shape exploration (paper S2.1) + dataflow planning
progs = [matmul_program(M, N, K, bm=bm, bn=bn, bk=bk)
         for bm, bn, bk in block_shape_candidates(M, N, K)]
res = plan_kernel_multi(progs, hw, budget=SearchBudget(top_k=5))
print("\n=== TileLoom two-step selection ===")
print(res.summary())
print("\n=== chosen dataflow (paper Listing 5 style) ===")
print(res.best.plan.mlir_like(hw))

print("\n=== vs vendor templates ===")
for name, mk in (("TT-1D", templates.tt1d_matmul_plan),
                 ("TT-2D", templates.tt2d_matmul_plan),
                 ("TTNN", templates.ttnn_matmul_plan)):
    t = simulate(mk(M, N, K, hw), hw)
    print(f"{name:6s}: {t.total_s * 1e6:8.1f} us  ({t.tflops:5.1f} TFLOP/s)")
best = res.best.sim
print(f"TL    : {best.total_s * 1e6:8.1f} us  ({best.tflops:5.1f} TFLOP/s)")

# -- pipeline co-planning: a 2-GEMM graph with on-chip forwarding -----------
# Chained kernels planned in isolation pay a DRAM store + reload for every
# producer->consumer intermediate.  The kernel-graph planner (repro.pipeline,
# DESIGN_PIPELINE.md) co-plans the chain and decides per edge whether the
# intermediate is *forwarded* through the distributed L1s or *spilled*.
from repro.pipeline import mlp2_graph, plan_pipeline

print("\n=== pipeline co-planning: 2-GEMM MLP (Y = X@W1; Z = Y@W2) ===")
graph = mlp2_graph(M=8192, d_model=128, d_ff=512)
gp = plan_pipeline(graph, hw, budget=SearchBudget(top_k=4))
for d in gp.decisions:
    print(f"edge {d.describe()}")
print(f"co-planned end-to-end:   {gp.total_s * 1e6:8.1f} us")
print(f"independent + DRAM trip: {gp.baseline_s * 1e6:8.1f} us "
      f"({gp.improvement:.2f}x)")
