"""End-to-end training example: a few hundred steps of a reduced qwen2.5-3b
with checkpointing and auto-resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    argv = sys.argv[1:] or ["--arch", "qwen2.5-3b", "--reduced",
                            "--steps", "300", "--batch", "8", "--seq", "128",
                            "--microbatches", "2", "--save-every", "100"]
    main(argv)
