"""Serving example: batched greedy decoding with a KV cache (reduced gemma).

    PYTHONPATH=src python examples/serve_decode.py
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    argv = sys.argv[1:] or ["--arch", "gemma-7b", "--reduced",
                            "--batch", "4", "--prompt-len", "8",
                            "--tokens", "24"]
    main(argv)
