"""Mesh-plan explorer: how TileLoom picks sharding layouts per (arch, shape).

    PYTHONPATH=src python examples/plan_explorer.py [arch ...]
"""
import sys

from repro.configs import ARCHS, SHAPES
from repro.configs.base import TrainConfig
from repro.models import build_model
from repro.parallel.planner_bridge import plan_mesh, tileloom_view

archs = sys.argv[1:] or ["qwen2.5-3b", "llama3-405b", "qwen3-moe-30b-a3b",
                         "rwkv6-3b"]
tcfg = TrainConfig(microbatches=4, opt_state_dtype="bfloat16")
for arch in archs:
    api = build_model(ARCHS[arch])
    print(f"\n=== {arch} ({api.n_params():,} params) ===")
    for shp in ("train_4k", "prefill_32k", "decode_32k"):
        ranked = plan_mesh(api, SHAPES[shp], tcfg)
        top = ranked[0]
        print(f"{shp:12s} -> {top.plan.name:18s} "
              f"dominant={top.cost.dominant:10s} "
              f"est={top.cost.total_s * 1e3:9.2f} ms/step "
              f"hbm={top.cost.hbm_bytes_per_chip / 1e9:5.1f} GB/chip")
    print("TileLoom view of the chosen train plan:")
    print(tileloom_view(plan_mesh(api, SHAPES['train_4k'], tcfg)[0].plan,
                        api.cfg))
